//! Integration (ISSUE 3 acceptance): the socket transport serves N
//! concurrent connections from ONE shared `ServingContext`. Two clients
//! sending identical batches: the second computes zero SV-set kernel rows
//! (and, for early models, zero routing dispatches), and socket decisions
//! are bit-identical to the stdio transport's output for the same model.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;

use dcsvm::data::synthetic::{covtype_like, generate_split};
use dcsvm::dcsvm::DcSvmConfig;
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::predict::SvmModel;
use dcsvm::serving::transport::{self, ServeClient, ServeCore};
use dcsvm::serving::{ServingContext, ServingModel};
use dcsvm::util::json::Json;

fn context_from_json(json: &Json, cache_mb: usize) -> ServingContext {
    let model = ServingModel::from_json(json).expect("model json loads");
    let kernel = Box::new(NativeKernel::new(model.kind()));
    ServingContext::new(model, kernel, cache_mb << 20)
}

/// Bind an ephemeral port and serve `core` from a background thread.
fn spawn_server(
    core: &Arc<ServeCore>,
    conn_workers: usize,
) -> (std::net::SocketAddr, JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let core = Arc::clone(core);
    let handle =
        std::thread::spawn(move || transport::run_listener(&core, listener, conn_workers));
    (addr, handle)
}

fn decision_bits(resp: &Json) -> Vec<u32> {
    resp.get("decisions")
        .as_arr()
        .expect("decisions array")
        .iter()
        .map(|v| (v.as_f64().expect("decision number") as f32).to_bits())
        .collect()
}

fn rows_of(x: &[f32], dim: usize) -> Vec<Vec<f32>> {
    x.chunks(dim).map(|r| r.to_vec()).collect()
}

#[test]
fn concurrent_clients_share_one_serving_cache() {
    let (tr, te) = generate_split(&covtype_like(), 400, 60, 21);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        ..Default::default()
    };
    let res = dcsvm::dcsvm::train(&tr, &kern, &cfg);
    let model = SvmModel::from_alpha(&tr, &res.alpha, kind);
    assert!(model.num_svs() > 0);
    let json = Json::parse(&model.to_json().to_string()).unwrap();
    let dim = te.dim;
    let n = te.len();

    // Stdio-transport reference output for the same model (cold cache):
    // the socket transport must serve bit-identical decision values.
    let stdio_core = ServeCore::new(context_from_json(&json, 16), 2);
    let mut out = Vec::new();
    let mut err = Vec::new();
    transport::run_stdio_io(
        &stdio_core,
        n,
        std::io::Cursor::new(dcsvm::data::libsvm::format_libsvm(&te)),
        &mut out,
        &mut err,
    )
    .unwrap();
    let stdio_text = String::from_utf8(out).unwrap();
    let stdio_bits: Vec<u32> = stdio_text
        .lines()
        .map(|l| l.split_whitespace().nth(1).unwrap().parse::<f32>().unwrap().to_bits())
        .collect();
    assert_eq!(stdio_bits.len(), n);

    // Socket server with one shared context, two concurrent connections.
    let core = Arc::new(ServeCore::new(context_from_json(&json, 16), 2));
    let (addr, server) = spawn_server(&core, 2);
    let rows = rows_of(&te.x, dim);
    let mut c1 = ServeClient::connect(addr).unwrap();
    let mut c2 = ServeClient::connect(addr).unwrap();
    let r1 = c1.decide(&rows).unwrap();
    let r2 = c2.decide(&rows).unwrap();
    assert_eq!(r1.get("error"), &Json::Null, "{r1}");
    assert_eq!(r2.get("error"), &Json::Null, "{r2}");

    // Client 1 paid the kernel work; client 2's identical batch computed
    // ZERO SV-set rows — served entirely from rows client 1 warmed.
    assert_eq!(r1.get("stats").get("rows_computed").as_f64(), Some(n as f64));
    assert_eq!(r1.get("stats").get("cache_hits").as_f64(), Some(0.0));
    assert_eq!(r2.get("stats").get("rows_computed").as_f64(), Some(0.0));
    assert_eq!(r2.get("stats").get("cache_hits").as_f64(), Some(n as f64));

    // Decisions: bit-identical across clients AND to the stdio transport.
    let (bits1, bits2) = (decision_bits(&r1), decision_bits(&r2));
    assert_eq!(bits1, bits2, "clients disagree");
    assert_eq!(bits1, stdio_bits, "socket and stdio transports disagree");

    // Graceful shutdown over the protocol. Client 2 stays CONNECTED and
    // idle: the server must close it at the next read-poll tick rather
    // than hang waiting for it (join would deadlock otherwise).
    let bye = c1.shutdown_server().unwrap();
    assert_eq!(bye.get("shutdown").as_bool(), Some(true));
    server.join().unwrap().unwrap();
    drop(c1);
    drop(c2);

    let summary = core.summary_json();
    assert_eq!(summary.get("batches").as_usize(), Some(2));
    assert_eq!(summary.get("served").as_usize(), Some(2 * n));
}

#[test]
fn warm_early_batches_skip_routing_dispatch_over_socket() {
    let (tr, te) = generate_split(&covtype_like(), 500, 80, 33);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        stop_after_level: Some(1),
        ..Default::default()
    };
    let res = dcsvm::dcsvm::train(&tr, &kern, &cfg);
    let em = res.early_model.expect("early model");
    let json = Json::parse(&em.to_json().to_string()).unwrap();

    let core = Arc::new(ServeCore::new(context_from_json(&json, 16), 2));
    let (addr, server) = spawn_server(&core, 2);
    let rows = rows_of(&te.x, te.dim);
    let mut c1 = ServeClient::connect(addr).unwrap();
    let mut c2 = ServeClient::connect(addr).unwrap();

    // Cold batch: exactly one K(batch, sample) routing dispatch.
    let r1 = c1.decide(&rows).unwrap();
    assert_eq!(r1.get("stats").get("routing_dispatches").as_f64(), Some(1.0));
    assert_eq!(r1.get("stats").get("routing_hits").as_f64(), Some(0.0));

    // Client 2 replays the batch: zero kernel work of ANY kind — no
    // SV-set rows and no routing dispatch.
    let r2 = c2.decide(&rows).unwrap();
    assert_eq!(r2.get("stats").get("rows_computed").as_f64(), Some(0.0));
    assert_eq!(r2.get("stats").get("routing_dispatches").as_f64(), Some(0.0));
    assert_eq!(
        r2.get("stats").get("routing_hits").as_f64(),
        Some(te.len() as f64)
    );
    assert_eq!(decision_bits(&r1), decision_bits(&r2));

    let bye = c1.shutdown_server().unwrap();
    assert_eq!(bye.get("ok").as_bool(), Some(true));
    drop(c1);
    drop(c2);
    server.join().unwrap().unwrap();
}

/// ISSUE satellite: the OVO ensemble over both transports. Socket
/// responses carry a `labels` array; the stdio transport emits
/// `label margin` lines — same model, bit-identical labels AND margins
/// across transports, and a second client's replayed batch computes zero
/// SV-block kernel rows.
#[test]
fn ovo_socket_and_stdio_transports_vote_identically() {
    use dcsvm::multiclass::{synthetic_multiclass, train_ovo};

    let tr = synthetic_multiclass(3, 240, 3, 13);
    let te = synthetic_multiclass(3, 40, 3, 14);
    let kind = KernelKind::Rbf { gamma: 2.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig { kind, c: 4.0, levels: 1, sample_m: 32, ..Default::default() };
    let model = train_ovo(&tr, &kern, &cfg);
    assert_eq!(model.machines.len(), 3);
    let json = Json::parse(&model.to_json().to_string()).unwrap();
    let n = te.len();

    // Stdio reference: one "label margin" line per query row.
    let stdio_core = ServeCore::new(context_from_json(&json, 16), 2);
    let mut out = Vec::new();
    let mut err = Vec::new();
    transport::run_stdio_io(
        &stdio_core,
        n,
        std::io::Cursor::new(dcsvm::data::libsvm::format_libsvm_multiclass(
            &te.x, &te.labels, te.dim,
        )),
        &mut out,
        &mut err,
    )
    .unwrap();
    let stdio_text = String::from_utf8(out).unwrap();
    let mut stdio_labels = Vec::new();
    let mut stdio_margin_bits = Vec::new();
    for line in stdio_text.lines() {
        let (l, m) = line.split_once(' ').expect("label margin");
        stdio_labels.push(l.parse::<u16>().expect("class id label"));
        stdio_margin_bits.push(m.parse::<f32>().unwrap().to_bits());
    }
    assert_eq!(stdio_labels.len(), n);

    // Socket transport, two clients sharing one context.
    let core = Arc::new(ServeCore::new(context_from_json(&json, 16), 2));
    let (addr, server) = spawn_server(&core, 2);
    let rows = rows_of(&te.x, te.dim);
    let mut c1 = ServeClient::connect(addr).unwrap();
    let mut c2 = ServeClient::connect(addr).unwrap();
    let r1 = c1.decide(&rows).unwrap();
    let r2 = c2.decide(&rows).unwrap();
    assert_eq!(r1.get("error"), &Json::Null, "{r1}");

    let socket_labels = |r: &Json| -> Vec<u16> {
        r.get("labels")
            .as_arr()
            .expect("ovo response carries labels")
            .iter()
            .map(|v| v.as_f64().unwrap() as u16)
            .collect()
    };
    assert_eq!(socket_labels(&r1), stdio_labels, "socket vs stdio labels");
    assert_eq!(decision_bits(&r1), stdio_margin_bits, "socket vs stdio margins");
    assert_eq!(socket_labels(&r2), stdio_labels, "second client's labels");
    assert_eq!(decision_bits(&r2), decision_bits(&r1));

    // Client 1 paid the per-class kernel rows; client 2's replay computed
    // ZERO SV-block rows — pure cache, across all three class blocks.
    let computed1 = r1.get("stats").get("rows_computed").as_f64().unwrap();
    assert!(computed1 > 0.0);
    assert_eq!(r2.get("stats").get("rows_computed").as_f64(), Some(0.0));
    assert_eq!(r2.get("stats").get("cache_hits").as_f64(), Some(computed1));
    // Multiclass counters flow over the wire.
    assert_eq!(r1.get("stats").get("pair_dispatches").as_f64(), Some(3.0));
    assert_eq!(r1.get("stats").get("votes").as_f64(), Some(3.0 * n as f64));
    assert_eq!(r1.get("stats").get("routing_dispatches").as_f64(), Some(0.0));

    let bye = c1.shutdown_server().unwrap();
    assert_eq!(bye.get("shutdown").as_bool(), Some(true));
    drop(c1);
    drop(c2);
    server.join().unwrap().unwrap();
    let summary = core.summary_json();
    assert_eq!(summary.get("pair_dispatches").as_f64(), Some(6.0), "{summary}");
    assert_eq!(summary.get("votes").as_f64(), Some(2.0 * 3.0 * n as f64), "{summary}");
}

/// Hand-built exact model over explicit dim-2 SV rows: the hot-swap test
/// needs exact control over which SV blocks change across the swap.
fn toy_model(svs: &[([f32; 2], f32)]) -> SvmModel {
    let mut sv_x = Vec::new();
    let mut coef = Vec::new();
    for (row, w) in svs {
        sv_x.extend_from_slice(row);
        coef.push(*w);
    }
    let sv_norms = sv_x.chunks(2).map(|r| r.iter().map(|&v| v * v).sum()).collect();
    SvmModel { sv_x, sv_norms, coef, dim: 2, kind: KernelKind::Rbf { gamma: 4.0 } }
}

fn expected_bits(model: &SvmModel, queries: &[f32]) -> Vec<u32> {
    let norms: Vec<f32> =
        queries.chunks(2).map(|q| q.iter().map(|&v| v * v).sum()).collect();
    let kern = NativeKernel::new(model.kind);
    model
        .decision_batch(queries, &norms, &kern)
        .iter()
        .map(|d| d.to_bits())
        .collect()
}

/// ISSUE 7 acceptance: clients hammer the TCP front-end while a
/// `swap_model` request lands. Every response must be bit-identical to
/// either the OLD model's decisions or the NEW model's decisions — never
/// a torn mix — and each connection flips old→new at most once (the
/// context snapshot is per batch). After the swap, replaying a pre-swap
/// query recomputes kernel rows ONLY for the SV blocks the update
/// changed; the unchanged blocks' cache entries survive the swap.
#[test]
fn hot_swap_under_load_is_never_torn_and_keeps_unchanged_blocks() {
    // Old model: 4 SVs, sv_block=2 → 2 FULL blocks [0,2) [2,4). The new
    // model keeps both bit-identical and appends 2 SVs as block [4,6),
    // exactly the shape `dcsvm update` produces when no old SV is
    // evicted: surviving SVs stay as the prefix, insertions append.
    let old_svs: Vec<([f32; 2], f32)> =
        vec![([0.1, 0.2], 0.5), ([0.3, 0.4], -0.25), ([0.5, 0.6], 0.75), ([0.7, 0.8], -0.5)];
    let mut new_svs = old_svs.clone();
    new_svs[1].1 = -0.6; // coef drift on a kept block: tags only pin SV rows
    new_svs.push(([1.1, 1.2], 0.4));
    new_svs.push(([1.3, 1.4], -0.3));
    let old_model = toy_model(&old_svs);
    let new_model = toy_model(&new_svs);

    let hammer: Vec<f32> = vec![0.15, 0.25, 0.65, 0.75]; // 2 queries
    let replay: Vec<f32> = vec![0.35, 0.45, 0.55, 0.05, 0.95, 0.85]; // 3 queries
    let old_hammer_bits = expected_bits(&old_model, &hammer);
    let new_hammer_bits = expected_bits(&new_model, &hammer);
    let new_replay_bits = expected_bits(&new_model, &replay);
    assert_ne!(old_hammer_bits, new_hammer_bits, "swap must be observable");

    // Serve the old model with swaps enabled.
    let ctx = ServingContext::with_block_size(
        ServingModel::Exact(old_model),
        Box::new(NativeKernel::new(KernelKind::Rbf { gamma: 4.0 })),
        4 << 20,
        2,
    );
    let factory: transport::KernelFactory =
        Box::new(|kind, _dim| Ok(Box::new(NativeKernel::new(kind))));
    let core = Arc::new(ServeCore::new(ctx, 2).with_swap(factory, 4 << 20));
    let (addr, server) = spawn_server(&core, 4);

    // Write the updated model where the server can load it.
    let dir = std::env::temp_dir().join(format!("dcsvm-swap-socket-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("updated.json");
    std::fs::write(&model_path, new_model.to_json().to_string()).unwrap();

    // Pre-swap: warm the replay batch on the old context (cold: 3 queries
    // × 2 blocks all computed).
    let mut warm = ServeClient::connect(addr).unwrap();
    let r0 = warm.decide(&rows_of(&replay, 2)).unwrap();
    assert_eq!(r0.get("error"), &Json::Null, "{r0}");
    assert_eq!(r0.get("stats").get("rows_computed").as_f64(), Some(6.0));

    // Hammer threads: replay the same batch back-to-back across the swap.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let hammers: Vec<_> = (0..3)
        .map(|_| {
            let stop = Arc::clone(&stop);
            let rows = rows_of(&hammer, 2);
            std::thread::spawn(move || {
                let mut client = ServeClient::connect(addr).unwrap();
                let mut seen = Vec::new();
                // Iteration cap: never hang the suite if the main thread
                // dies before flipping `stop`.
                for _ in 0..100_000 {
                    let resp = client.decide(&rows).unwrap();
                    assert_eq!(resp.get("error"), &Json::Null, "{resp}");
                    seen.push(decision_bits(&resp));
                    if stop.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                }
                seen
            })
        })
        .collect();

    // Let the hammers land some old-model batches, then swap mid-load.
    std::thread::sleep(std::time::Duration::from_millis(30));
    let mut swapper = ServeClient::connect(addr).unwrap();
    let sw = swapper.swap_model(model_path.to_str().unwrap()).unwrap();
    assert_eq!(sw.get("swapped").as_bool(), Some(true), "{sw}");
    assert_eq!(sw.get("svs").as_usize(), Some(6));
    assert_eq!(sw.get("blocks_total").as_usize(), Some(3));
    assert_eq!(sw.get("blocks_kept").as_usize(), Some(2), "both full old blocks survive");
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);

    // Never torn: every response is exactly the old model's bits or
    // exactly the new model's, and each connection transitions at most
    // once (a later batch can never see an earlier model).
    for h in hammers {
        let seen = h.join().unwrap();
        assert!(!seen.is_empty());
        let mut switched = false;
        for bits in &seen {
            if *bits == new_hammer_bits {
                switched = true;
            } else {
                assert_eq!(*bits, &old_hammer_bits[..], "torn response");
                assert!(!switched, "old-model response AFTER a new-model response");
            }
        }
    }

    // Post-swap replay of the pre-swap query: the two unchanged SV blocks
    // are served from the entries warmed BEFORE the swap (zero recomputed
    // rows for them); only the appended block computes.
    let r1 = warm.decide(&rows_of(&replay, 2)).unwrap();
    assert_eq!(r1.get("error"), &Json::Null, "{r1}");
    assert_eq!(
        r1.get("stats").get("cache_hits").as_f64(),
        Some(6.0),
        "unchanged blocks must survive the swap: {r1}"
    );
    assert_eq!(
        r1.get("stats").get("rows_computed").as_f64(),
        Some(3.0),
        "only the appended SV block recomputes: {r1}"
    );
    assert_eq!(decision_bits(&r1), new_replay_bits, "replay serves the NEW model");

    // And a warm re-replay computes nothing at all.
    let r2 = warm.decide(&rows_of(&replay, 2)).unwrap();
    assert_eq!(r2.get("stats").get("rows_computed").as_f64(), Some(0.0));

    let bye = warm.shutdown_server().unwrap();
    assert_eq!(bye.get("shutdown").as_bool(), Some(true));
    drop(warm);
    drop(swapper);
    server.join().unwrap().unwrap();
    assert_eq!(core.swaps(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_requests_get_error_objects_not_disconnects() {
    use std::io::{BufRead, BufReader, Write};

    // Zero-SV exact model: cheap, full request path.
    let (tr, _) = generate_split(&covtype_like(), 40, 10, 2);
    let kind = KernelKind::Rbf { gamma: 1.0 };
    let model = SvmModel::from_alpha(&tr, &vec![0.0; tr.len()], kind);
    let json = Json::parse(&model.to_json().to_string()).unwrap();
    let core = Arc::new(ServeCore::new(context_from_json(&json, 4), 1));
    let (addr, server) = spawn_server(&core, 1);
    let dim = core.ctx().dim();

    fn roundtrip(
        reader: &mut BufReader<std::net::TcpStream>,
        stream: &mut std::net::TcpStream,
        req: &[u8],
    ) -> Json {
        stream.write_all(req).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim_end()).unwrap()
    }

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // Invalid JSON → structured `parse` error, connection survives.
    let resp = roundtrip(&mut reader, &mut stream, b"this is not json\n");
    assert_eq!(resp.get("error").get("code").as_str(), Some("parse"));

    // Wrong dimension → `dim_mismatch`.
    let resp = roundtrip(&mut reader, &mut stream, b"{\"x\": [[1.0, 2.0, 3.0]]}\n");
    assert_eq!(resp.get("error").get("code").as_str(), Some("dim_mismatch"));

    // Missing "x" → `bad_request`, id echoed.
    let resp = roundtrip(&mut reader, &mut stream, b"{\"id\": 9, \"y\": []}\n");
    assert_eq!(resp.get("error").get("code").as_str(), Some("bad_request"));
    assert_eq!(resp.get("id").as_f64(), Some(9.0));

    // The SAME connection still serves valid requests after the errors.
    let req = transport::decide_request(None, &[vec![0.5f32; dim]]).to_string() + "\n";
    let resp = roundtrip(&mut reader, &mut stream, req.as_bytes());
    assert_eq!(resp.get("error"), &Json::Null, "{resp}");
    assert_eq!(resp.get("stats").get("rows").as_usize(), Some(1));

    let resp = roundtrip(&mut reader, &mut stream, b"{\"shutdown\": true}\n");
    assert_eq!(resp.get("shutdown").as_bool(), Some(true));
    drop(stream);
    server.join().unwrap().unwrap();
}
