//! Integration (ISSUE 3 acceptance): the socket transport serves N
//! concurrent connections from ONE shared `ServingContext`. Two clients
//! sending identical batches: the second computes zero SV-set kernel rows
//! (and, for early models, zero routing dispatches), and socket decisions
//! are bit-identical to the stdio transport's output for the same model.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;

use dcsvm::data::synthetic::{covtype_like, generate_split};
use dcsvm::dcsvm::DcSvmConfig;
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::predict::SvmModel;
use dcsvm::serving::transport::{self, ServeClient, ServeCore};
use dcsvm::serving::{ServingContext, ServingModel};
use dcsvm::util::json::Json;

fn context_from_json(json: &Json, cache_mb: usize) -> ServingContext {
    let model = ServingModel::from_json(json).expect("model json loads");
    let kernel = Box::new(NativeKernel::new(model.kind()));
    ServingContext::new(model, kernel, cache_mb << 20)
}

/// Bind an ephemeral port and serve `core` from a background thread.
fn spawn_server(
    core: &Arc<ServeCore>,
    conn_workers: usize,
) -> (std::net::SocketAddr, JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let core = Arc::clone(core);
    let handle =
        std::thread::spawn(move || transport::run_listener(&core, listener, conn_workers));
    (addr, handle)
}

fn decision_bits(resp: &Json) -> Vec<u32> {
    resp.get("decisions")
        .as_arr()
        .expect("decisions array")
        .iter()
        .map(|v| (v.as_f64().expect("decision number") as f32).to_bits())
        .collect()
}

fn rows_of(x: &[f32], dim: usize) -> Vec<Vec<f32>> {
    x.chunks(dim).map(|r| r.to_vec()).collect()
}

#[test]
fn concurrent_clients_share_one_serving_cache() {
    let (tr, te) = generate_split(&covtype_like(), 400, 60, 21);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        ..Default::default()
    };
    let res = dcsvm::dcsvm::train(&tr, &kern, &cfg);
    let model = SvmModel::from_alpha(&tr, &res.alpha, kind);
    assert!(model.num_svs() > 0);
    let json = Json::parse(&model.to_json().to_string()).unwrap();
    let dim = te.dim;
    let n = te.len();

    // Stdio-transport reference output for the same model (cold cache):
    // the socket transport must serve bit-identical decision values.
    let stdio_core = ServeCore::new(context_from_json(&json, 16), 2);
    let mut out = Vec::new();
    let mut err = Vec::new();
    transport::run_stdio_io(
        &stdio_core,
        n,
        std::io::Cursor::new(dcsvm::data::libsvm::format_libsvm(&te)),
        &mut out,
        &mut err,
    )
    .unwrap();
    let stdio_text = String::from_utf8(out).unwrap();
    let stdio_bits: Vec<u32> = stdio_text
        .lines()
        .map(|l| l.split_whitespace().nth(1).unwrap().parse::<f32>().unwrap().to_bits())
        .collect();
    assert_eq!(stdio_bits.len(), n);

    // Socket server with one shared context, two concurrent connections.
    let core = Arc::new(ServeCore::new(context_from_json(&json, 16), 2));
    let (addr, server) = spawn_server(&core, 2);
    let rows = rows_of(&te.x, dim);
    let mut c1 = ServeClient::connect(addr).unwrap();
    let mut c2 = ServeClient::connect(addr).unwrap();
    let r1 = c1.decide(&rows).unwrap();
    let r2 = c2.decide(&rows).unwrap();
    assert_eq!(r1.get("error"), &Json::Null, "{r1}");
    assert_eq!(r2.get("error"), &Json::Null, "{r2}");

    // Client 1 paid the kernel work; client 2's identical batch computed
    // ZERO SV-set rows — served entirely from rows client 1 warmed.
    assert_eq!(r1.get("stats").get("rows_computed").as_f64(), Some(n as f64));
    assert_eq!(r1.get("stats").get("cache_hits").as_f64(), Some(0.0));
    assert_eq!(r2.get("stats").get("rows_computed").as_f64(), Some(0.0));
    assert_eq!(r2.get("stats").get("cache_hits").as_f64(), Some(n as f64));

    // Decisions: bit-identical across clients AND to the stdio transport.
    let (bits1, bits2) = (decision_bits(&r1), decision_bits(&r2));
    assert_eq!(bits1, bits2, "clients disagree");
    assert_eq!(bits1, stdio_bits, "socket and stdio transports disagree");

    // Graceful shutdown over the protocol. Client 2 stays CONNECTED and
    // idle: the server must close it at the next read-poll tick rather
    // than hang waiting for it (join would deadlock otherwise).
    let bye = c1.shutdown_server().unwrap();
    assert_eq!(bye.get("shutdown").as_bool(), Some(true));
    server.join().unwrap().unwrap();
    drop(c1);
    drop(c2);

    let summary = core.summary_json();
    assert_eq!(summary.get("batches").as_usize(), Some(2));
    assert_eq!(summary.get("served").as_usize(), Some(2 * n));
}

#[test]
fn warm_early_batches_skip_routing_dispatch_over_socket() {
    let (tr, te) = generate_split(&covtype_like(), 500, 80, 33);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        stop_after_level: Some(1),
        ..Default::default()
    };
    let res = dcsvm::dcsvm::train(&tr, &kern, &cfg);
    let em = res.early_model.expect("early model");
    let json = Json::parse(&em.to_json().to_string()).unwrap();

    let core = Arc::new(ServeCore::new(context_from_json(&json, 16), 2));
    let (addr, server) = spawn_server(&core, 2);
    let rows = rows_of(&te.x, te.dim);
    let mut c1 = ServeClient::connect(addr).unwrap();
    let mut c2 = ServeClient::connect(addr).unwrap();

    // Cold batch: exactly one K(batch, sample) routing dispatch.
    let r1 = c1.decide(&rows).unwrap();
    assert_eq!(r1.get("stats").get("routing_dispatches").as_f64(), Some(1.0));
    assert_eq!(r1.get("stats").get("routing_hits").as_f64(), Some(0.0));

    // Client 2 replays the batch: zero kernel work of ANY kind — no
    // SV-set rows and no routing dispatch.
    let r2 = c2.decide(&rows).unwrap();
    assert_eq!(r2.get("stats").get("rows_computed").as_f64(), Some(0.0));
    assert_eq!(r2.get("stats").get("routing_dispatches").as_f64(), Some(0.0));
    assert_eq!(
        r2.get("stats").get("routing_hits").as_f64(),
        Some(te.len() as f64)
    );
    assert_eq!(decision_bits(&r1), decision_bits(&r2));

    let bye = c1.shutdown_server().unwrap();
    assert_eq!(bye.get("ok").as_bool(), Some(true));
    drop(c1);
    drop(c2);
    server.join().unwrap().unwrap();
}

#[test]
fn malformed_requests_get_error_objects_not_disconnects() {
    use std::io::{BufRead, BufReader, Write};

    // Zero-SV exact model: cheap, full request path.
    let (tr, _) = generate_split(&covtype_like(), 40, 10, 2);
    let kind = KernelKind::Rbf { gamma: 1.0 };
    let model = SvmModel::from_alpha(&tr, &vec![0.0; tr.len()], kind);
    let json = Json::parse(&model.to_json().to_string()).unwrap();
    let core = Arc::new(ServeCore::new(context_from_json(&json, 4), 1));
    let (addr, server) = spawn_server(&core, 1);
    let dim = core.ctx().dim();

    fn roundtrip(
        reader: &mut BufReader<std::net::TcpStream>,
        stream: &mut std::net::TcpStream,
        req: &[u8],
    ) -> Json {
        stream.write_all(req).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim_end()).unwrap()
    }

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // Invalid JSON → structured `parse` error, connection survives.
    let resp = roundtrip(&mut reader, &mut stream, b"this is not json\n");
    assert_eq!(resp.get("error").get("code").as_str(), Some("parse"));

    // Wrong dimension → `dim_mismatch`.
    let resp = roundtrip(&mut reader, &mut stream, b"{\"x\": [[1.0, 2.0, 3.0]]}\n");
    assert_eq!(resp.get("error").get("code").as_str(), Some("dim_mismatch"));

    // Missing "x" → `bad_request`, id echoed.
    let resp = roundtrip(&mut reader, &mut stream, b"{\"id\": 9, \"y\": []}\n");
    assert_eq!(resp.get("error").get("code").as_str(), Some("bad_request"));
    assert_eq!(resp.get("id").as_f64(), Some(9.0));

    // The SAME connection still serves valid requests after the errors.
    let req = transport::decide_request(None, &[vec![0.5f32; dim]]).to_string() + "\n";
    let resp = roundtrip(&mut reader, &mut stream, req.as_bytes());
    assert_eq!(resp.get("error"), &Json::Null, "{resp}");
    assert_eq!(resp.get("stats").get("rows").as_usize(), Some(1));

    let resp = roundtrip(&mut reader, &mut stream, b"{\"shutdown\": true}\n");
    assert_eq!(resp.get("shutdown").as_bool(), Some(true));
    drop(stream);
    server.join().unwrap().unwrap();
}
