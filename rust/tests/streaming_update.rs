//! Integration (ISSUE 7 acceptance): the streaming-update drift scenario.
//!
//! A synthetic label-drifting stream — the labeling rule flips at every
//! chunk boundary — is consumed chunk by chunk with warm [`update`]s
//! seeded from the previous model. The harness proves the three claims
//! `dcsvm update` makes:
//!
//! (a) accuracy on each drifted chunk RECOVERS after its update (the
//!     stale model scores badly on the new rule, the updated one well);
//! (b) every warm update computes STRICTLY FEWER kernel values than a
//!     cold retrain on the same cumulative data ([`cold_solve`] is the
//!     comparator, `--compare-cold` gates the same claim in bench CI);
//! (c) an empty delta is a bit-identical no-op on the model JSON —
//!     checked at the CLI level, where `dcsvm update` must copy the model
//!     file bytes through verbatim.

use std::path::PathBuf;
use std::process::Command;

use dcsvm::data::synthetic::{covtype_like, generate};
use dcsvm::data::Dataset;
use dcsvm::dcsvm::update::{cold_solve, seed_from_model, update, UpdateConfig};
use dcsvm::kernel::native::NativeKernel;
use dcsvm::kernel::KernelKind;
use dcsvm::predict::SvmModel;
use dcsvm::util::json::Json;
use dcsvm::util::prng::Pcg64;

fn flipped(ds: &Dataset, name: &str) -> Dataset {
    Dataset::new(ds.x.clone(), ds.y.iter().map(|&l| -l).collect(), ds.dim, name)
}

fn test_cfg() -> UpdateConfig {
    UpdateConfig { c: 4.0, cache_bytes: 8 << 20, threads: 1, ..UpdateConfig::default() }
}

/// (a) + (b): three chunks, the labeling rule flips at every boundary.
/// Each update must recover accuracy on its chunk AND cost strictly less
/// kernel work than retraining from scratch on everything seen so far.
#[test]
fn drift_stream_recovers_accuracy_with_fewer_kernel_values_than_retrain() {
    let spec = covtype_like();
    let mut rng = Pcg64::new(17);
    let kern = NativeKernel::new(KernelKind::Rbf { gamma: 16.0 });
    let cfg = test_cfg();

    // chunk 0: base rule; chunk 1: rule flipped; chunk 2: flipped back.
    let base = generate(&spec, 120, &mut rng);
    let drift1 = flipped(&generate(&spec, 120, &mut rng), "drift-1");
    let drift2 = generate(&spec, 120, &mut rng);

    let mut model = cold_solve(&base, &kern, &cfg).model;
    assert!(model.num_svs() > 0);
    let mut cumulative = base;

    for (step, chunk) in [&drift1, &drift2].into_iter().enumerate() {
        let stale = model.accuracy(chunk, &kern);
        let res = update(&model, chunk, &kern, &cfg)
            .unwrap_or_else(|e| panic!("update at drift {step}: {e:#}"));
        assert!(!res.noop);
        let fresh = res.model.accuracy(chunk, &kern);

        // (a) the update absorbs the flipped rule: the stale model is at
        // or below chance-ish on the drifted chunk, the fresh one is not.
        assert!(
            fresh >= 0.7,
            "drift {step}: updated model did not learn its chunk (acc {fresh})"
        );
        assert!(
            fresh > stale + 0.1,
            "drift {step}: no recovery margin (stale {stale}, fresh {fresh})"
        );

        // (b) warm vs cold on the same cumulative stream.
        cumulative = cumulative.appended(chunk, "cumulative");
        let cold = cold_solve(&cumulative, &kern, &cfg);
        assert!(
            res.values_computed < cold.values_computed,
            "drift {step}: warm update ({}) must beat cold retrain ({}) on {} rows",
            res.values_computed,
            cold.values_computed,
            cumulative.len()
        );

        // SV bookkeeping holds across the whole stream.
        assert_eq!(
            res.model.num_svs() as u64,
            model.num_svs() as u64 + res.svs_added - res.svs_dropped
        );
        model = res.model;
    }
}

/// The warm solve is not an approximation: on the SAME subproblem
/// (`SVs ∪ delta`, reconstructed via [`seed_from_model`]) a warm-started
/// solve and a cold solve converge to the same dual objective within
/// ±1e-6 (relative) once both run to a tight KKT tolerance.
#[test]
fn warm_solve_matches_cold_objective_on_the_same_subproblem() {
    let spec = covtype_like();
    let mut rng = Pcg64::new(23);
    let kern = NativeKernel::new(KernelKind::Rbf { gamma: 16.0 });
    let cfg = UpdateConfig { eps: 1e-9, ..test_cfg() };

    let base = generate(&spec, 90, &mut rng);
    let delta = generate(&spec, 30, &mut rng);
    let model = cold_solve(&base, &kern, &cfg).model;

    let warm = update(&model, &delta, &kern, &cfg).unwrap();
    let (seed_ds, _) = seed_from_model(&model, cfg.c);
    let working = seed_ds.appended(&delta, "working");
    let cold = cold_solve(&working, &kern, &cfg);

    let scale = 1.0 + warm.objective.abs().max(cold.objective.abs());
    assert!(
        (warm.objective - cold.objective).abs() <= 1e-6 * scale,
        "objectives diverge: warm {} vs cold {}",
        warm.objective,
        cold.objective
    );
}

// ---------------------------------------------------------------------------
// CLI-level checks: the `dcsvm update` binary round-trip.

fn bin() -> PathBuf {
    // target dir of the test binary: target/debug/deps/... → target/debug
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("dcsvm")
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(bin())
        .args(args)
        .env("DCSVM_LOG", "warn")
        .output()
        .expect("spawn dcsvm");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// The one JSON line `dcsvm update` prints on stdout.
fn stdout_json(stdout: &str) -> Json {
    let line = stdout
        .lines()
        .find(|l| l.trim_start().starts_with('{'))
        .unwrap_or_else(|| panic!("no JSON line on stdout: {stdout}"));
    Json::parse(line.trim()).expect("update stdout parses as JSON")
}

/// (c) empty delta → `--out` is BYTE-identical to `--model`, and every
/// update counter is zero (`bench_diff.py` gates the same invariant on
/// the bench-smoke no-op leg).
#[test]
fn cli_empty_delta_copies_the_model_file_byte_identically() {
    let dir = std::env::temp_dir().join("dcsvm_cli_update_noop");
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.json");
    let delta_path = dir.join("empty.libsvm");
    let out_path = dir.join("updated.json");

    let spec = covtype_like();
    let mut rng = Pcg64::new(31);
    let base = generate(&spec, 80, &mut rng);
    let kern = NativeKernel::new(KernelKind::Rbf { gamma: 16.0 });
    let model = cold_solve(&base, &kern, &test_cfg()).model;
    std::fs::write(&model_path, model.to_json().to_string()).unwrap();
    std::fs::write(&delta_path, "").unwrap();

    let (ok, stdout, stderr) = run(&[
        "update",
        "--model",
        model_path.to_str().unwrap(),
        "--data",
        delta_path.to_str().unwrap(),
        "--out",
        out_path.to_str().unwrap(),
        "--backend",
        "native",
    ]);
    assert!(ok, "{stdout}{stderr}");

    let j = stdout_json(&stdout);
    assert_eq!(j.get("noop").as_bool(), Some(true), "{j}");
    assert_eq!(j.get("update_values_computed").as_f64(), Some(0.0), "{j}");
    assert_eq!(j.get("svs_added").as_f64(), Some(0.0), "{j}");
    assert_eq!(j.get("svs_dropped").as_f64(), Some(0.0), "{j}");

    let original = std::fs::read(&model_path).unwrap();
    let copied = std::fs::read(&out_path).unwrap();
    assert_eq!(original, copied, "no-op update must be byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full CLI drift leg bench-smoke runs in CI: update with a drifted
/// delta, `--compare-cold` on the cumulative data, and assert the warm
/// update reports strictly fewer kernel values than the cold retrain.
#[test]
fn cli_update_with_compare_cold_reports_warm_beats_cold() {
    let dir = std::env::temp_dir().join("dcsvm_cli_update_cold");
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.json");
    let delta_path = dir.join("delta.libsvm");
    let cumulative_path = dir.join("cumulative.libsvm");
    let out_path = dir.join("updated.json");

    let spec = covtype_like();
    let mut rng = Pcg64::new(37);
    let kern = NativeKernel::new(KernelKind::Rbf { gamma: 16.0 });
    let base = generate(&spec, 100, &mut rng);
    let delta = flipped(&generate(&spec, 50, &mut rng), "drift");
    let model = cold_solve(&base, &kern, &test_cfg()).model;

    std::fs::write(&model_path, model.to_json().to_string()).unwrap();
    std::fs::write(&delta_path, dcsvm::data::libsvm::format_libsvm(&delta)).unwrap();
    let cumulative = base.appended(&delta, "cumulative");
    std::fs::write(&cumulative_path, dcsvm::data::libsvm::format_libsvm(&cumulative))
        .unwrap();

    let (ok, stdout, stderr) = run(&[
        "update",
        "--model",
        model_path.to_str().unwrap(),
        "--data",
        delta_path.to_str().unwrap(),
        "--out",
        out_path.to_str().unwrap(),
        "--c",
        "4",
        "--backend",
        "native",
        "--compare-cold",
        cumulative_path.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}{stderr}");

    let j = stdout_json(&stdout);
    assert_eq!(j.get("noop").as_bool(), Some(false), "{j}");
    let warm = j.get("update_values_computed").as_f64().unwrap();
    let cold = j.get("cold_values_computed").as_f64().unwrap();
    assert!(warm > 0.0, "{j}");
    assert!(warm < cold, "warm {warm} !< cold {cold}: {j}");
    assert_eq!(j.get("warm_beats_cold").as_bool(), Some(true), "{j}");

    // The emitted model loads and still serves the drifted chunk well.
    let text = std::fs::read_to_string(&out_path).unwrap();
    let updated = SvmModel::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert!(updated.num_svs() > 0);
    assert!(
        updated.accuracy(&delta, &kern) >= 0.7,
        "updated model forgot its delta"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
