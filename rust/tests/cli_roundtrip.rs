//! Integration: CLI binary round-trips — train → save model → predict,
//! config file handling, and every subcommand smoke-tested.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target dir of the test binary: target/debug/deps/... → target/debug
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("dcsvm")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .env("DCSVM_LOG", "warn")
        .output()
        .expect("spawn dcsvm");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn datasets_lists_all_seven() {
    let (ok, text) = run(&["datasets"]);
    assert!(ok, "{text}");
    for name in [
        "ijcnn1-like",
        "cifar-like",
        "census-like",
        "covtype-like",
        "webspam-like",
        "kddcup99-like",
        "mnist8m-like",
    ] {
        assert!(text.contains(name), "missing {name}: {text}");
    }
}

#[test]
fn train_save_predict_roundtrip() {
    let dir = std::env::temp_dir().join("dcsvm_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    let (ok, text) = run(&[
        "train",
        "--algo",
        "dcsvm",
        "--dataset",
        "covtype-like",
        "--n-train",
        "400",
        "--n-test",
        "150",
        "--gamma",
        "16",
        "--c",
        "4",
        "--levels",
        "2",
        "--sample-m",
        "64",
        "--backend",
        "native",
        "--save-model",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("model saved"), "{text}");

    let (ok, text) = run(&[
        "predict",
        "--model",
        model.to_str().unwrap(),
        "--dataset",
        "covtype-like",
        "--n-train",
        "400",
        "--n-test",
        "150",
        "--backend",
        "native",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("acc="), "{text}");
    std::fs::remove_file(&model).ok();
}

#[test]
fn config_file_plus_override() {
    let dir = std::env::temp_dir().join("dcsvm_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.json");
    std::fs::write(
        &cfg,
        r#"{"dataset": "ijcnn1-like", "gamma": 2.0, "c": 32.0, "n_train": 300, "n_test": 100, "backend": "native", "levels": 2, "sample_m": 64}"#,
    )
    .unwrap();
    let (ok, text) = run(&[
        "train",
        "--config",
        cfg.to_str().unwrap(),
        "--algo",
        "libsvm",
        "--gamma",
        "8", // override the file
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("γ=8"), "override lost: {text}");
    assert!(text.contains("ijcnn1-like"), "{text}");
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn kmeans_subcommand_reports_partition() {
    let (ok, text) = run(&[
        "kmeans",
        "--dataset",
        "covtype-like",
        "--n-train",
        "500",
        "--n-test",
        "50",
        "--k-base",
        "4",
        "--sample-m",
        "64",
        "--backend",
        "native",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("two-step kernel kmeans"), "{text}");
    assert!(text.contains("D(π)"), "{text}");
}

#[test]
fn info_and_help_work() {
    let (ok, text) = run(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("PJRT backend"), "{text}");
    let (ok, text) = run(&["help"]);
    assert!(ok);
    assert!(text.contains("commands:"));
}

#[test]
fn serve_rejects_bad_flags() {
    // Missing value for --backend used to silently become "".
    let (ok, text) = run(&["serve", "--model", "m.json", "--backend"]);
    assert!(!ok);
    assert!(text.contains("needs a value"), "{text}");
    // Unparsable --batch used to silently fall back to 256.
    let (ok, text) = run(&["serve", "--model", "m.json", "--batch", "abc"]);
    assert!(!ok);
    assert!(text.contains("--batch"), "{text}");
    assert!(text.contains("usage:"), "{text}");
    let (ok, text) = run(&["serve", "--model", "m.json", "--workers", "0"]);
    assert!(!ok);
    assert!(text.contains("--workers"), "{text}");
    let (ok, text) = run(&["serve", "--batch", "8"]);
    assert!(!ok);
    assert!(text.contains("requires --model"), "{text}");
    // --listen is a KNOWN flag (the stale-usage bug): a missing value must
    // error with the generated usage, never as "unknown flag".
    let (ok, text) = run(&["serve", "--model", "m.json", "--listen"]);
    assert!(!ok);
    assert!(text.contains("needs a value"), "{text}");
    assert!(!text.contains("unknown flag"), "{text}");
    let (ok, text) = run(&["serve", "--model", "m.json", "--conns", "0"]);
    assert!(!ok);
    assert!(text.contains("--conns"), "{text}");
    let (ok, text) = run(&["serve", "--model", "m.json", "--verbose", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown flag"), "{text}");
}

#[test]
fn update_rejects_bad_flags() {
    // Missing required flags: strict usage bail, not a file error.
    let (ok, text) = run(&["update"]);
    assert!(!ok);
    assert!(text.contains("requires --model"), "{text}");
    let (ok, text) = run(&["update", "--model", "m.json"]);
    assert!(!ok);
    assert!(text.contains("requires --data"), "{text}");
    // A known flag with a missing value errors as such, never "unknown".
    let (ok, text) = run(&["update", "--model", "m.json", "--data"]);
    assert!(!ok);
    assert!(text.contains("needs a value"), "{text}");
    assert!(!text.contains("unknown flag"), "{text}");
    // Unparsable numerics name the flag and print the usage.
    let (ok, text) = run(&["update", "--model", "m.json", "--data", "d", "--c", "abc"]);
    assert!(!ok);
    assert!(text.contains("--c"), "{text}");
    assert!(text.contains("usage:"), "{text}");
    let (ok, text) =
        run(&["update", "--model", "m.json", "--data", "d", "--cache-mb", "0"]);
    assert!(!ok);
    assert!(text.contains("--cache-mb"), "{text}");
    // Unknown flags are rejected up front.
    let (ok, text) = run(&["update", "--model", "m.json", "--data", "d", "--bogus", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown flag"), "{text}");
}

#[test]
fn update_help_prints_the_full_flag_table() {
    let (ok, text) = run(&["update", "--help"]);
    assert!(ok, "{text}");
    assert!(text.contains("usage: dcsvm update"), "{text}");
    for flag in [
        "--model",
        "--data",
        "--out",
        "--c",
        "--eps",
        "--max-iter",
        "--cache-mb",
        "--backend",
        "--threads",
        "--compare-cold",
    ] {
        assert!(text.contains(flag), "usage missing {flag}: {text}");
    }
}

#[test]
fn serve_help_lists_every_flag_from_the_shared_table() {
    let (ok, text) = run(&["serve", "--help"]);
    assert!(ok, "{text}");
    assert!(text.contains("usage: dcsvm serve"), "{text}");
    // The usage text is generated from the same table README renders, so
    // neither can drift from the parser (which tests/docs_sync.rs pins to
    // README.md).
    for f in dcsvm::serving::transport::SERVE_FLAGS {
        assert!(text.contains(f.flag), "usage missing {}: {text}", f.flag);
        assert!(text.contains(f.help), "usage missing help for {}: {text}", f.flag);
    }
}

#[test]
fn serve_listen_socket_matches_stdio_transport() {
    use std::io::{BufRead, BufReader, Write};
    use std::process::{Command, Stdio};

    let dir = std::env::temp_dir().join("dcsvm_cli_listen");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("listen_model.json");
    let (ok, text) = run(&[
        "train",
        "--algo",
        "dcsvm",
        "--dataset",
        "covtype-like",
        "--n-train",
        "300",
        "--n-test",
        "100",
        "--gamma",
        "16",
        "--c",
        "4",
        "--levels",
        "2",
        "--sample-m",
        "64",
        "--backend",
        "native",
        "--save-model",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");

    // One small batch, shared by both transports.
    let spec = dcsvm::data::synthetic::all_specs()
        .into_iter()
        .find(|s| s.name == "covtype-like")
        .unwrap();
    let (_, te) = dcsvm::data::synthetic::generate_split(&spec, 50, 12, 5);
    let libsvm = dcsvm::data::libsvm::format_libsvm(&te);

    // 1) stdio transport.
    let mut child = Command::new(bin())
        .args([
            "serve",
            "--model",
            model.to_str().unwrap(),
            "--backend",
            "native",
            "--workers",
            "2",
        ])
        .env("DCSVM_LOG", "warn")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn dcsvm serve (stdio)");
    child.stdin.take().unwrap().write_all(libsvm.as_bytes()).unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdio_bits: Vec<u32> = String::from_utf8_lossy(&out.stdout)
        .lines()
        .map(|l| l.split_whitespace().nth(1).unwrap().parse::<f32>().unwrap().to_bits())
        .collect();
    assert_eq!(stdio_bits.len(), te.len());

    // 2) socket transport: bind an ephemeral port and discover it from the
    //    {"listening": ...} stderr line.
    let mut child = Command::new(bin())
        .args([
            "serve",
            "--model",
            model.to_str().unwrap(),
            "--backend",
            "native",
            "--workers",
            "2",
            "--listen",
            "127.0.0.1:0",
        ])
        .env("DCSVM_LOG", "warn")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn dcsvm serve (socket)");
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        assert!(
            stderr.read_line(&mut line).unwrap() > 0,
            "server exited before announcing its address"
        );
        if let Ok(j) = dcsvm::util::json::Json::parse(line.trim_end()) {
            if let Some(a) = j.get("listening").as_str() {
                break a.to_string();
            }
        }
    };
    let rows: Vec<Vec<f32>> = te.x.chunks(te.dim).map(|r| r.to_vec()).collect();
    let mut client =
        dcsvm::serving::transport::ServeClient::connect(addr.as_str()).unwrap();
    let resp = client.decide(&rows).unwrap();
    assert_eq!(resp.get("error"), &dcsvm::util::json::Json::Null, "{resp}");
    let socket_bits: Vec<u32> = resp
        .get("decisions")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| (v.as_f64().unwrap() as f32).to_bits())
        .collect();
    assert_eq!(
        socket_bits, stdio_bits,
        "socket and stdio transports must serve bit-identical decisions"
    );

    let bye = client.shutdown_server().unwrap();
    assert_eq!(bye.get("shutdown").as_bool(), Some(true));
    drop(client);
    let status = child.wait().unwrap();
    assert!(status.success());
    std::fs::remove_file(&model).ok();
}

#[test]
fn serve_roundtrip_emits_predictions_and_warm_batch_stats() {
    use std::io::Write;
    use std::process::{Command, Stdio};

    let dir = std::env::temp_dir().join("dcsvm_cli_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("serve_model.json");
    let (ok, text) = run(&[
        "train",
        "--algo",
        "dcsvm",
        "--dataset",
        "covtype-like",
        "--n-train",
        "300",
        "--n-test",
        "100",
        "--gamma",
        "16",
        "--c",
        "4",
        "--levels",
        "2",
        "--sample-m",
        "64",
        "--backend",
        "native",
        "--save-model",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");

    // Build a small LIBSVM request batch and send it TWICE: the second
    // batch must be served from the persistent cross-request cache.
    let spec = dcsvm::data::synthetic::all_specs()
        .into_iter()
        .find(|s| s.name == "covtype-like")
        .unwrap();
    let (_, te) = dcsvm::data::synthetic::generate_split(&spec, 50, 16, 0);
    let batch = dcsvm::data::libsvm::format_libsvm(&te);
    let n = te.len();

    let mut child = Command::new(bin())
        .args([
            "serve",
            "--model",
            model.to_str().unwrap(),
            "--batch",
            &n.to_string(),
            "--workers",
            "2",
            "--backend",
            "native",
        ])
        .env("DCSVM_LOG", "warn")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn dcsvm serve");
    {
        let mut stdin = child.stdin.take().unwrap();
        stdin.write_all(batch.as_bytes()).unwrap();
        stdin.write_all(batch.as_bytes()).unwrap();
    } // dropped → EOF
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Two identical batches → 2n prediction lines, pairwise identical.
    let stdout = String::from_utf8_lossy(&out.stdout);
    let preds: Vec<&str> = stdout.lines().collect();
    assert_eq!(preds.len(), 2 * n, "stdout: {stdout}");
    assert_eq!(&preds[..n], &preds[n..], "identical batches must serve identically");

    // Per-batch JSON stats on stderr: batch 0 cold, batch 1 fully warm.
    let stderr = String::from_utf8_lossy(&out.stderr);
    let stats: Vec<dcsvm::util::json::Json> = stderr
        .lines()
        .filter(|l| l.starts_with('{'))
        .map(|l| dcsvm::util::json::Json::parse(l).expect("stats line parses"))
        .collect();
    assert!(stats.len() >= 3, "expected 2 batch lines + summary: {stderr}");
    let (b0, b1) = (&stats[0], &stats[1]);
    assert_eq!(b0.get("rows").as_usize(), Some(n));
    let hits0 = b0.get("cache_hits").as_f64().unwrap();
    let hits1 = b1.get("cache_hits").as_f64().unwrap();
    assert!(hits1 > hits0, "warm batch hits {hits1} !> cold {hits0}");
    assert_eq!(b1.get("rows_computed").as_f64(), Some(0.0), "{stderr}");
    let summary = stats.last().unwrap();
    assert_eq!(summary.get("served").as_usize(), Some(2 * n));
    assert_eq!(summary.get("batches").as_usize(), Some(2));

    std::fs::remove_file(&model).ok();
}

#[test]
fn train_saves_and_serves_early_model() {
    use std::io::Write;
    use std::process::{Command, Stdio};

    let dir = std::env::temp_dir().join("dcsvm_cli_early");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("early_model.json");
    let (ok, text) = run(&[
        "train",
        "--algo",
        "early",
        "--dataset",
        "covtype-like",
        "--n-train",
        "400",
        "--n-test",
        "100",
        "--gamma",
        "16",
        "--c",
        "4",
        "--levels",
        "2",
        "--sample-m",
        "64",
        "--backend",
        "native",
        "--save-model",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("model saved"), "{text}");

    let spec = dcsvm::data::synthetic::all_specs()
        .into_iter()
        .find(|s| s.name == "covtype-like")
        .unwrap();
    let (_, te) = dcsvm::data::synthetic::generate_split(&spec, 50, 8, 3);
    let batch = dcsvm::data::libsvm::format_libsvm(&te);

    let mut child = Command::new(bin())
        .args(["serve", "--model", model.to_str().unwrap(), "--backend", "native"])
        .env("DCSVM_LOG", "warn")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn dcsvm serve");
    {
        let mut stdin = child.stdin.take().unwrap();
        stdin.write_all(batch.as_bytes()).unwrap();
    } // dropped → EOF
    let out = child.wait_with_output().unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "{stderr}");
    assert!(stderr.contains("early(k="), "not served as an early model: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.lines().count(), te.len(), "{stdout}");

    std::fs::remove_file(&model).ok();
}

/// ISSUE satellite: strict `--algo` parsing — `ovo` accepted, junk
/// rejected with a named error, missing values rejected, usage names ovo,
/// and the `mc<K>` dataset pattern is validated.
#[test]
fn train_algo_flag_is_strict_and_knows_ovo() {
    let (ok, text) = run(&["train", "--algo", "bogus"]);
    assert!(!ok);
    assert!(text.contains("unknown algo"), "{text}");
    let (ok, text) = run(&["train", "--algo"]);
    assert!(!ok);
    assert!(text.contains("needs a value"), "{text}");
    let (ok, text) = run(&["help"]);
    assert!(ok);
    assert!(text.contains("ovo"), "usage must name --algo ovo: {text}");
    // mc<K> needs at least 2 classes.
    let (ok, text) =
        run(&["train", "--algo", "ovo", "--dataset", "mc1", "--backend", "native"]);
    assert!(!ok);
    assert!(text.contains("mc<K>"), "{text}");
}

/// ISSUE tentpole (CLI leg): `train --algo ovo --save-model` writes ONE
/// ensemble JSON that `dcsvm serve` loads and serves — stdout lines are
/// `label margin`, the model describes itself as ovo, and a replayed
/// batch is served entirely from the cross-request cache.
#[test]
fn ovo_train_save_serve_roundtrip() {
    use std::io::Write;
    use std::process::{Command, Stdio};

    let dir = std::env::temp_dir().join("dcsvm_cli_ovo");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("ovo_model.json");
    let (ok, text) = run(&[
        "train",
        "--algo",
        "ovo",
        "--dataset",
        "mc4",
        "--n-train",
        "320",
        "--n-test",
        "80",
        "--gamma",
        "2",
        "--c",
        "4",
        "--levels",
        "1",
        "--sample-m",
        "32",
        "--backend",
        "native",
        "--save-model",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("machines=6"), "4 classes → 6 machines: {text}");
    assert!(text.contains("pair_dispatches=6"), "{text}");
    assert!(text.contains("model saved"), "{text}");

    // Multiclass query rows (same dim-4 space as mc4), sent TWICE.
    let qs = dcsvm::multiclass::synthetic_multiclass(4, 12, 4, 9);
    let batch =
        dcsvm::data::libsvm::format_libsvm_multiclass(&qs.x, &qs.labels, qs.dim);
    let n = qs.len();
    let mut child = Command::new(bin())
        .args([
            "serve",
            "--model",
            model.to_str().unwrap(),
            "--batch",
            &n.to_string(),
            "--workers",
            "2",
            "--backend",
            "native",
        ])
        .env("DCSVM_LOG", "warn")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn dcsvm serve (ovo)");
    {
        let mut stdin = child.stdin.take().unwrap();
        stdin.write_all(batch.as_bytes()).unwrap();
        stdin.write_all(batch.as_bytes()).unwrap();
    } // dropped → EOF
    let out = child.wait_with_output().unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "{stderr}");
    assert!(stderr.contains("ovo(classes=4, machines=6)"), "{stderr}");

    // 2n `label margin` lines, labels valid class ids, batches identical.
    let stdout = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = stdout.lines().collect();
    assert_eq!(lines.len(), 2 * n, "{stdout}");
    for line in &lines {
        let (l, m) = line.split_once(' ').expect("label margin");
        let label: u16 = l.parse().expect("class id label");
        assert!(label < 4, "label {label} out of range: {line}");
        let margin: f32 = m.parse().expect("margin");
        assert!(margin >= 0.0, "vote margins are non-negative: {line}");
    }
    assert_eq!(&lines[..n], &lines[n..], "replayed batch must vote identically");

    // Batch stats: cold pays per-class rows, warm replay computes none;
    // the multiclass counters ride along.
    let stats: Vec<dcsvm::util::json::Json> = stderr
        .lines()
        .filter(|l| l.starts_with('{'))
        .map(|l| dcsvm::util::json::Json::parse(l).expect("stats line parses"))
        .collect();
    assert!(stats.len() >= 3, "expected 2 batch lines + summary: {stderr}");
    let (b0, b1) = (&stats[0], &stats[1]);
    assert_eq!(b0.get("pair_dispatches").as_f64(), Some(6.0), "{stderr}");
    assert_eq!(b0.get("votes").as_f64(), Some(6.0 * n as f64), "{stderr}");
    assert!(b0.get("rows_computed").as_f64().unwrap() > 0.0, "{stderr}");
    assert_eq!(b1.get("rows_computed").as_f64(), Some(0.0), "{stderr}");
    std::fs::remove_file(&model).ok();
}

/// ISSUE satellite: `dcsvm worker` parses its flags from the shared
/// declarative table — strict unknown-flag rejection, missing-value
/// errors, required `--listen`, and a `--help` listing every flag.
#[test]
fn worker_flags_are_strict_and_table_driven() {
    let (ok, text) = run(&["worker"]);
    assert!(!ok);
    assert!(text.contains("requires --listen"), "{text}");
    let (ok, text) = run(&["worker", "--listen"]);
    assert!(!ok);
    assert!(text.contains("needs a value"), "{text}");
    assert!(!text.contains("unknown flag"), "{text}");
    let (ok, text) = run(&["worker", "--bogus", "1"]);
    assert!(!ok);
    assert!(text.contains("worker: unknown flag '--bogus'"), "{text}");
    let (ok, text) = run(&["worker", "--listen", "127.0.0.1:0", "--cache-mb", "0"]);
    assert!(!ok);
    assert!(text.contains("--cache-mb"), "{text}");
    assert!(text.contains("usage:"), "{text}");
    let (ok, text) = run(&["worker", "--help"]);
    assert!(ok, "{text}");
    assert!(text.contains("usage: dcsvm worker --listen ADDR"), "{text}");
    for f in dcsvm::distributed::WORKER_FLAGS {
        assert!(text.contains(f.flag), "usage missing {}: {text}", f.flag);
        assert!(text.contains(f.help), "usage missing help for {}: {text}", f.flag);
    }
}

/// ISSUE tentpole (CLI leg): `train --distributed true` spawns local
/// `dcsvm worker` child processes of the real binary, trains over the
/// wire protocol, and reports the communication counters.
#[test]
fn distributed_train_spawns_local_workers_end_to_end() {
    let (ok, text) = run(&[
        "train",
        "--distributed",
        "true",
        "--workers",
        "2",
        "--rounds",
        "2",
        "--dataset",
        "covtype-like",
        "--n-train",
        "200",
        "--n-test",
        "60",
        "--gamma",
        "16",
        "--c",
        "4",
        "--backend",
        "native",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("Distributed:"), "{text}");
    assert!(text.contains("comm_bytes="), "{text}");
    assert!(text.contains("rounds=2"), "{text}");
    assert!(text.contains("workers=2 spawned=true"), "{text}");
    assert!(text.contains("objective"), "{text}");

    // Flag validation flows through RunConfig like every train flag.
    let (ok, text) = run(&["train", "--distributed", "maybe"]);
    assert!(!ok);
    assert!(text.contains("--distributed"), "{text}");
    let (ok, text) = run(&["train", "--rounds", "many"]);
    assert!(!ok);
    assert!(text.contains("--rounds"), "{text}");
    // Saving a model needs the single-process path.
    let (ok, text) =
        run(&["train", "--distributed", "true", "--save-model", "/tmp/m.json"]);
    assert!(!ok);
    assert!(text.contains("--save-model is not supported"), "{text}");
}

#[test]
fn unknown_command_fails_cleanly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
}

#[test]
fn bad_flag_rejected() {
    let (ok, text) = run(&["train", "--nonsense", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown config key"), "{text}");
}
