//! Integration: CLI binary round-trips — train → save model → predict,
//! config file handling, and every subcommand smoke-tested.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target dir of the test binary: target/debug/deps/... → target/debug
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("dcsvm")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .env("DCSVM_LOG", "warn")
        .output()
        .expect("spawn dcsvm");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn datasets_lists_all_seven() {
    let (ok, text) = run(&["datasets"]);
    assert!(ok, "{text}");
    for name in [
        "ijcnn1-like",
        "cifar-like",
        "census-like",
        "covtype-like",
        "webspam-like",
        "kddcup99-like",
        "mnist8m-like",
    ] {
        assert!(text.contains(name), "missing {name}: {text}");
    }
}

#[test]
fn train_save_predict_roundtrip() {
    let dir = std::env::temp_dir().join("dcsvm_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    let (ok, text) = run(&[
        "train",
        "--algo",
        "dcsvm",
        "--dataset",
        "covtype-like",
        "--n-train",
        "400",
        "--n-test",
        "150",
        "--gamma",
        "16",
        "--c",
        "4",
        "--levels",
        "2",
        "--sample-m",
        "64",
        "--backend",
        "native",
        "--save-model",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("model saved"), "{text}");

    let (ok, text) = run(&[
        "predict",
        "--model",
        model.to_str().unwrap(),
        "--dataset",
        "covtype-like",
        "--n-train",
        "400",
        "--n-test",
        "150",
        "--backend",
        "native",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("acc="), "{text}");
    std::fs::remove_file(&model).ok();
}

#[test]
fn config_file_plus_override() {
    let dir = std::env::temp_dir().join("dcsvm_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.json");
    std::fs::write(
        &cfg,
        r#"{"dataset": "ijcnn1-like", "gamma": 2.0, "c": 32.0, "n_train": 300, "n_test": 100, "backend": "native", "levels": 2, "sample_m": 64}"#,
    )
    .unwrap();
    let (ok, text) = run(&[
        "train",
        "--config",
        cfg.to_str().unwrap(),
        "--algo",
        "libsvm",
        "--gamma",
        "8", // override the file
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("γ=8"), "override lost: {text}");
    assert!(text.contains("ijcnn1-like"), "{text}");
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn kmeans_subcommand_reports_partition() {
    let (ok, text) = run(&[
        "kmeans",
        "--dataset",
        "covtype-like",
        "--n-train",
        "500",
        "--n-test",
        "50",
        "--k-base",
        "4",
        "--sample-m",
        "64",
        "--backend",
        "native",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("two-step kernel kmeans"), "{text}");
    assert!(text.contains("D(π)"), "{text}");
}

#[test]
fn info_and_help_work() {
    let (ok, text) = run(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("PJRT backend"), "{text}");
    let (ok, text) = run(&["help"]);
    assert!(ok);
    assert!(text.contains("commands:"));
}

#[test]
fn unknown_command_fails_cleanly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
}

#[test]
fn bad_flag_rejected() {
    let (ok, text) = run(&["train", "--nonsense", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown config key"), "{text}");
}
