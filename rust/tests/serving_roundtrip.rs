//! Regression (ISSUE 2 acceptance): serialize a trained model, push two
//! identical request batches through a persistent `ServingContext`, and
//! prove the second batch computes strictly fewer kernel rows (cache hits
//! > 0, zero rows computed) while producing bit-identical decisions.

use dcsvm::data::synthetic::{covtype_like, generate_split};
use dcsvm::dcsvm::DcSvmConfig;
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::multiclass::{synthetic_multiclass, train_ovo};
use dcsvm::predict::SvmModel;
use dcsvm::serving::{ServingContext, ServingModel};
use dcsvm::util::json::Json;

fn serve_roundtrip(model_json: Json, queries: &[f32], workers: usize) {
    let model = ServingModel::from_json(&model_json).expect("model json loads");
    let kernel = Box::new(NativeKernel::new(model.kind()));
    let ctx = ServingContext::new(model, kernel, 16 << 20);

    let (dv1, s1) = ctx.decide(queries, workers);
    assert!(s1.rows > 0);
    assert_eq!(s1.cache_hits, 0, "cold batch must not hit the serving cache");
    assert!(s1.rows_computed > 0, "cold batch must compute kernel rows");

    let (dv2, s2) = ctx.decide(queries, workers);
    assert_eq!(dv1, dv2, "identical batches must produce bit-identical decisions");
    assert!(
        s2.cache_hits > s1.cache_hits,
        "second batch hits ({}) must exceed first ({})",
        s2.cache_hits,
        s1.cache_hits
    );
    assert!(
        s2.rows_computed < s1.rows_computed,
        "second batch must compute strictly fewer kernel rows ({} vs {})",
        s2.rows_computed,
        s1.rows_computed
    );
    assert_eq!(s2.rows_computed, 0, "fully warm batch computes nothing");
}

#[test]
fn exact_model_reuses_kernel_rows_across_request_batches() {
    let (tr, te) = generate_split(&covtype_like(), 500, 160, 42);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        ..Default::default()
    };
    let res = dcsvm::dcsvm::train(&tr, &kern, &cfg);
    let model = SvmModel::from_alpha(&tr, &res.alpha, kind);
    assert!(model.num_svs() > 0);

    // Serialize → reparse, exactly as `dcsvm train --save-model` +
    // `dcsvm serve` do.
    let json = Json::parse(&model.to_json().to_string()).unwrap();
    serve_roundtrip(json, &te.x, 2);
}

#[test]
fn early_model_reuses_kernel_rows_across_request_batches() {
    let (tr, te) = generate_split(&covtype_like(), 600, 150, 17);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        stop_after_level: Some(1),
        ..Default::default()
    };
    let res = dcsvm::dcsvm::train(&tr, &kern, &cfg);
    let em = res.early_model.expect("early model");
    let json = Json::parse(&em.to_json().to_string()).unwrap();
    serve_roundtrip(json, &te.x, 3);
}

/// ISSUE satellite: an OVO ensemble behind the same persistent context —
/// a replayed batch computes ZERO SV-block rows while every decision
/// (vote margin) stays bit-identical.
#[test]
fn ovo_model_reuses_kernel_rows_across_request_batches() {
    let tr = synthetic_multiclass(4, 320, 4, 9);
    let te = synthetic_multiclass(4, 60, 4, 10);
    let kind = KernelKind::Rbf { gamma: 2.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig { kind, c: 4.0, levels: 1, sample_m: 32, ..Default::default() };
    let model = train_ovo(&tr, &kern, &cfg);
    assert_eq!(model.machines.len(), 6);
    let json = Json::parse(&model.to_json().to_string()).unwrap();
    serve_roundtrip(json, &te.x, 2);
}

/// ISSUE satellite: serving an OVO model returns the same labels and vote
/// margins the offline predictor computes — the serving fold IS the
/// offline fold, with kernel rows assembled per class block.
#[test]
fn ovo_serving_labels_match_offline_votes() {
    let tr = synthetic_multiclass(3, 240, 4, 11);
    let te = synthetic_multiclass(3, 50, 4, 12);
    let kind = KernelKind::Rbf { gamma: 2.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig { kind, c: 4.0, levels: 1, sample_m: 32, ..Default::default() };
    let model = train_ovo(&tr, &kern, &cfg);
    let norms: Vec<f32> = te
        .x
        .chunks(te.dim)
        .map(|r| r.iter().map(|&v| v * v).sum())
        .collect();
    let offline = model.predict_with_margins(&te.x, &norms, &kern);

    let serving =
        ServingModel::from_json(&Json::parse(&model.to_json().to_string()).unwrap()).unwrap();
    let ctx = ServingContext::new(serving, Box::new(NativeKernel::new(kind)), 8 << 20);
    let (dv, labels, stats) = ctx.decide_full(&te.x, 2);
    let labels = labels.expect("OVO batches carry voted labels");
    assert_eq!(labels.len(), te.len());
    for (i, &(want_l, want_m)) in offline.iter().enumerate() {
        assert_eq!(labels[i], want_l, "query {i}: label");
        assert_eq!(dv[i].to_bits(), want_m.to_bits(), "query {i}: margin");
    }
    // Multiclass counters: every machine voted on every row.
    assert_eq!(stats.pair_dispatches, model.machines.len() as u64);
    assert_eq!(stats.votes, (model.machines.len() * te.len()) as u64);
    // Binary models leave them zero.
    let (_, no_labels, bstats) = {
        let (trb, teb) = generate_split(&covtype_like(), 120, 20, 3);
        let res = dcsvm::dcsvm::train(
            &trb,
            &NativeKernel::new(KernelKind::Rbf { gamma: 16.0 }),
            &DcSvmConfig {
                kind: KernelKind::Rbf { gamma: 16.0 },
                c: 4.0,
                levels: 1,
                sample_m: 32,
                ..Default::default()
            },
        );
        let m = SvmModel::from_alpha(&trb, &res.alpha, KernelKind::Rbf { gamma: 16.0 });
        let sm = ServingModel::from_json(&Json::parse(&m.to_json().to_string()).unwrap())
            .unwrap();
        let bctx = ServingContext::new(
            sm,
            Box::new(NativeKernel::new(KernelKind::Rbf { gamma: 16.0 })),
            4 << 20,
        );
        bctx.decide_full(&teb.x, 1)
    };
    assert!(no_labels.is_none(), "binary batches carry no labels");
    assert_eq!(bstats.pair_dispatches, 0);
    assert_eq!(bstats.votes, 0);
}

/// The serving path must agree with the offline prediction path on signs
/// (accuracy parity between `dcsvm predict` and `dcsvm serve`).
#[test]
fn serving_predictions_match_offline_model() {
    let (tr, te) = generate_split(&covtype_like(), 400, 120, 7);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kern = NativeKernel::new(kind);
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        ..Default::default()
    };
    let res = dcsvm::dcsvm::train(&tr, &kern, &cfg);
    let model = SvmModel::from_alpha(&tr, &res.alpha, kind);
    let norms = te.sq_norms();
    let offline = model.predict_batch(&te.x, &norms, &kern);

    let serving = ServingModel::from_json(&Json::parse(&model.to_json().to_string()).unwrap())
        .unwrap();
    let ctx = ServingContext::new(serving, Box::new(NativeKernel::new(kind)), 8 << 20);
    let (preds, _) = ctx.predict(&te.x, 2);
    assert_eq!(preds, offline);
}
