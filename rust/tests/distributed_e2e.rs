//! Distributed parallel block minimization, end to end over real sockets:
//! a loopback protocol round-trip, the 2-worker vs single-process
//! equivalence gate (same dual objective, same accuracy, α summaries only
//! on the wire), and the fault matrix — a worker that exits, stalls past
//! `--round-timeout`, or garbles mid-round is re-sharded onto survivors
//! and the run still matches the single-process solve; losing every
//! worker aborts with a structured error (never a hang); a killed
//! locally-spawned worker is respawned under `--worker-retries`.
//!
//! Workers run as in-process threads on ephemeral listeners
//! (`run_worker` serves one session per process in production), with
//! deterministic faults injected via [`WorkerOptions::fault`]. The
//! respawn path needs a real child process to kill and replace, so that
//! test drives the actual binary with the [`FAULT_ENV`] directive.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use dcsvm::cache::KernelContext;
use dcsvm::config::RunConfig;
use dcsvm::distributed::{
    ids_json, run_worker, train_distributed, FaultKind, FaultPlan, Hello, WorkerOptions,
};
use dcsvm::harness;
use dcsvm::predict::SvmModel;
use dcsvm::solver::{SmoConfig, SmoSolver};
use dcsvm::util::json::Json;
use dcsvm::util::wire::{self, Frame, TcpCodec};

/// A real worker on an ephemeral loopback port, serving one session,
/// optionally with a deterministic injected fault.
fn spawn_worker_with(fault: Option<FaultPlan>) -> (String, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = WorkerOptions { threads: 2, cache_mb: 64, backend: "native".into(), fault };
    let h = std::thread::spawn(move || run_worker(listener, &opts).unwrap());
    (addr, h)
}

fn spawn_worker() -> (String, JoinHandle<()>) {
    spawn_worker_with(None)
}

fn dist_cfg(addrs: &[String], n_train: usize, n_test: usize, eps: f64) -> RunConfig {
    RunConfig {
        dataset: "covtype-like".into(),
        n_train: Some(n_train),
        n_test: Some(n_test),
        gamma: 16.0,
        c: 4.0,
        eps,
        backend: "native".into(),
        distributed: true,
        rounds: 2,
        workers_addr: Some(addrs.join(",")),
        ..RunConfig::default()
    }
}

/// The single-process comparator: one exact solve at `cfg.eps` on the
/// same split, returning (objective, accuracy).
fn single_process_reference(cfg: &RunConfig) -> (f64, f64) {
    let (tr, te) = harness::load_dataset(cfg).unwrap();
    let kind = cfg.kernel_kind().unwrap();
    let kernel = harness::make_kernel(kind, "native", tr.dim).unwrap();
    let ctx = KernelContext::new(&tr, kernel.as_ref(), 64 << 20).with_threads(2);
    let res = SmoSolver::new(
        ctx.view_full(),
        SmoConfig { c: cfg.c, eps: cfg.eps, ..SmoConfig::default() },
    )
    .solve();
    let model = SvmModel::from_ctx_alpha(&ctx, &res.alpha);
    let te_ctx = KernelContext::new(&te, kernel.as_ref(), 1 << 20).with_threads(2);
    (res.objective, model.accuracy_ctx(&te_ctx))
}

fn read_json(codec: &mut TcpCodec) -> Json {
    loop {
        match codec.read_frame().unwrap() {
            Frame::Line(l) => {
                let t = l.trim();
                if t.is_empty() {
                    continue;
                }
                return Json::parse(t).unwrap();
            }
            Frame::Idle => continue,
            other => panic!("unexpected frame: {other:?}"),
        }
    }
}

/// Loopback unit round-trip: hello → shard → round → reshard → structured
/// protocol error → shutdown, one worker, manual coordinator side.
#[test]
fn loopback_worker_session_roundtrip() {
    let (addr, h) = spawn_worker();
    let mut codec = wire::tcp_codec(TcpStream::connect(&addr).unwrap()).unwrap();

    let hello = Hello {
        dataset: "covtype-like".into(),
        n_train: 120,
        n_test: 40,
        seed: 0,
        kernel: "rbf".into(),
        gamma: 16.0,
        eta: 0.0,
        c: 4.0,
        eps: 1e-3,
    };
    codec.write_json(&Json::obj(vec![("hello", hello.to_json())])).unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    assert_eq!(r.get("n").as_usize(), Some(120), "{r}");

    let shard: Vec<usize> = (0..120).step_by(2).collect();
    codec.write_json(&Json::obj(vec![("shard", ids_json(&shard))])).unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    assert_eq!(r.get("rows").as_usize(), Some(60), "{r}");

    // Round 1: no external summaries yet — a plain block solve.
    codec
        .write_json(&Json::obj(vec![
            ("round", Json::from(1usize)),
            ("ext_ids", Json::Arr(vec![])),
            ("ext_alpha", Json::Arr(vec![])),
        ]))
        .unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("round").as_usize(), Some(1), "{r}");
    let ids = r.get("ids").as_arr().unwrap();
    let al = r.get("alpha").as_arr().unwrap();
    assert_eq!(ids.len(), al.len());
    assert!(!ids.is_empty(), "a solved block has support vectors");
    for v in ids {
        let i = v.as_usize().unwrap();
        assert!(shard.contains(&i), "summary id {i} outside the shard");
    }
    assert!(r.get("objective").as_f64().is_some(), "{r}");
    assert!(r.get("values_computed").as_f64().unwrap() > 0.0, "{r}");

    // Re-shard: adopt the odd rows (with warm seeds), as the coordinator
    // does when their previous owner is lost. The ack reports the NEW
    // total shard size.
    let adopted: Vec<usize> = (1..120).step_by(2).collect();
    codec
        .write_json(&Json::obj(vec![
            ("reshard", ids_json(&adopted)),
            ("alpha", Json::arr_f64(&vec![0.5; adopted.len()])),
        ]))
        .unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    assert_eq!(r.get("rows").as_usize(), Some(120), "{r}");

    // Re-sharding a row the worker already owns is a structured error
    // (the session continues).
    codec.write_json(&Json::obj(vec![("reshard", ids_json(&[0usize]))])).unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("error").get("code").as_str(), Some("bad_request"), "{r}");

    // The next round solves the grown shard: summaries may now cover any
    // of the 120 rows.
    codec
        .write_json(&Json::obj(vec![
            ("round", Json::from(2usize)),
            ("ext_ids", Json::Arr(vec![])),
            ("ext_alpha", Json::Arr(vec![])),
        ]))
        .unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("round").as_usize(), Some(2), "{r}");
    assert!(!r.get("ids").as_arr().unwrap().is_empty(), "{r}");

    // Mismatched ext arrays → structured protocol error, session continues.
    codec
        .write_json(&Json::obj(vec![
            ("round", Json::from(3usize)),
            ("ext_ids", ids_json(&[0usize])),
            ("ext_alpha", Json::Arr(vec![])),
        ]))
        .unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("error").get("code").as_str(), Some("protocol"), "{r}");

    codec.write_json(&Json::obj(vec![("shutdown", Json::from(true))])).unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    drop(codec);
    h.join().unwrap();
}

/// The equivalence gate: a 2-worker distributed run must land on the same
/// ε-KKT solution as a single-process solve — same dual objective (1e-6
/// relative), same test accuracy — while moving only α summaries over the
/// wire (orders of magnitude below one serialized kernel block).
#[test]
fn two_worker_run_matches_single_process() {
    let (a0, h0) = spawn_worker();
    let (a1, h1) = spawn_worker();
    let cfg = dist_cfg(&[a0, a1], 300, 100, 1e-8);
    let (tr, te) = harness::load_dataset(&cfg).unwrap();

    let out = train_distributed(&cfg, &tr, &te).unwrap();
    h0.join().unwrap();
    h1.join().unwrap();

    let (os, acc_single) = single_process_reference(&cfg);
    let od = out.objective.unwrap();
    assert!(
        (od - os).abs() <= 1e-6 * (1.0 + os.abs()),
        "distributed objective {od} vs single-process {os}"
    );
    assert_eq!(
        out.accuracy, acc_single,
        "distributed and single-process models must classify identically"
    );

    // Communication efficiency: the whole run's wire traffic stays far
    // below ONE serialized kernel block (n² f32 entries).
    let comm = out.comm_bytes.expect("comm_bytes recorded");
    let kernel_block_bytes = (tr.len() * tr.len() * 4) as u64;
    assert!(comm > 0);
    assert!(
        comm < kernel_block_bytes / 4,
        "comm_bytes {comm} not ≪ kernel block {kernel_block_bytes}"
    );
    assert_eq!(out.rounds, Some(2));
    assert!(out.worker_values_computed.expect("worker values recorded") > 0);
    assert_eq!(out.algo, "Distributed");
    assert!(out.note.contains("workers=2"), "note: {}", out.note);
    assert!(out.note.contains("spawned=false"), "note: {}", out.note);

    // A clean run records the recovery counters as explicit zeros.
    assert_eq!(out.workers_lost, Some(0));
    assert_eq!(out.resharded_rows, Some(0));
    assert_eq!(out.rounds_replayed, Some(0));
    assert_eq!(out.respawns, Some(0));
}

/// Fault matrix, exit: worker 1 closes its connection mid-round-2 without
/// replying. The coordinator re-shards its rows onto worker 0, replays
/// the round, and the run still matches the single-process solve to 1e-6
/// relative objective and exact accuracy.
#[test]
fn worker_exit_mid_round_reshards_and_matches_single_process() {
    let (a0, h0) = spawn_worker();
    let (a1, h1) =
        spawn_worker_with(Some(FaultPlan { round: 2, kind: FaultKind::Exit }));
    let cfg = dist_cfg(&[a0, a1], 300, 100, 1e-8);
    let (tr, te) = harness::load_dataset(&cfg).unwrap();

    let out = train_distributed(&cfg, &tr, &te).unwrap();
    h0.join().unwrap();
    h1.join().unwrap();

    assert_eq!(out.workers_lost, Some(1), "note: {}", out.note);
    assert!(
        out.resharded_rows.unwrap() > 0,
        "the lost worker's rows must move to the survivor"
    );
    assert!(out.rounds_replayed.unwrap() >= 1, "the interrupted round must replay");
    assert_eq!(out.respawns, Some(0), "attached workers are never respawned");
    assert_eq!(out.rounds, Some(2));

    let (os, acc_single) = single_process_reference(&cfg);
    let od = out.objective.unwrap();
    assert!(
        (od - os).abs() <= 1e-6 * (1.0 + os.abs()),
        "post-recovery objective {od} vs single-process {os}"
    );
    assert_eq!(
        out.accuracy, acc_single,
        "a run that lost a worker must still classify identically"
    );
}

/// Fault matrix, stall: worker 1 stops replying mid-round-2 but holds its
/// connection open — only the `--round-timeout` deadline can catch it.
/// Recovery and the equivalence gates are identical to the exit case.
#[test]
fn worker_stall_past_round_timeout_reshards_and_matches() {
    let (a0, h0) = spawn_worker();
    let (a1, h1) =
        spawn_worker_with(Some(FaultPlan { round: 2, kind: FaultKind::Stall }));
    let mut cfg = dist_cfg(&[a0, a1], 240, 80, 1e-8);
    cfg.round_timeout = 2.0; // stall detection = deadline, not EOF

    let (tr, te) = harness::load_dataset(&cfg).unwrap();
    let t0 = std::time::Instant::now();
    let out = train_distributed(&cfg, &tr, &te).unwrap();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "stall recovery took {:?}",
        t0.elapsed()
    );
    // Retiring the stalled worker closes its connection, which unblocks
    // the stalled thread — both joins must return promptly.
    h0.join().unwrap();
    h1.join().unwrap();

    assert_eq!(out.workers_lost, Some(1), "note: {}", out.note);
    assert!(out.resharded_rows.unwrap() > 0);
    assert!(out.rounds_replayed.unwrap() >= 1);
    assert_eq!(out.respawns, Some(0));

    let (os, acc_single) = single_process_reference(&cfg);
    let od = out.objective.unwrap();
    assert!(
        (od - os).abs() <= 1e-6 * (1.0 + os.abs()),
        "post-recovery objective {od} vs single-process {os}"
    );
    assert_eq!(out.accuracy, acc_single);
}

/// Fault matrix, garbage: worker 1 answers round 1 with a syntactically
/// valid line that is not a round reply. The coordinator must treat it as
/// a lost worker (not crash, not accept it) and recover by re-sharding.
#[test]
fn worker_garbage_reply_is_retired_and_the_run_recovers() {
    let (a0, h0) = spawn_worker();
    let (a1, h1) =
        spawn_worker_with(Some(FaultPlan { round: 1, kind: FaultKind::Garbage }));
    let cfg = dist_cfg(&[a0, a1], 160, 60, 1e-4);
    let (tr, te) = harness::load_dataset(&cfg).unwrap();

    let out = train_distributed(&cfg, &tr, &te).unwrap();
    h0.join().unwrap();
    h1.join().unwrap();

    assert_eq!(out.workers_lost, Some(1), "note: {}", out.note);
    // Lost in round 1: no committed summary yet, so the moved rows carry
    // zero seeds — but they all move.
    assert_eq!(out.resharded_rows, Some(80));
    assert!(out.rounds_replayed.unwrap() >= 1);
    assert!(out.accuracy > 0.5, "recovered run must still train a real model");
}

/// A protocol-fluent stub that dies between rounds: answers hello and
/// shard, reads round 1, then drops the connection without replying.
fn spawn_stub_worker_dying_mid_round(n: usize) -> (String, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut write = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // hello
        writeln!(
            write,
            "{}",
            Json::obj(vec![("ok", Json::from(true)), ("n", Json::from(n))])
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap(); // shard
        writeln!(
            write,
            "{}",
            Json::obj(vec![("ok", Json::from(true)), ("rows", Json::from(1usize))])
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap(); // round 1 — die without replying
    });
    (addr, h)
}

/// Losing EVERY worker is the one unrecoverable case: with nothing left
/// to re-shard onto, the run must abort with a structured `worker_lost`
/// error promptly (within read-poll ticks, not a hang).
#[test]
fn losing_all_workers_aborts_with_a_structured_error() {
    let (a0, h0) = spawn_stub_worker_dying_mid_round(100);
    let (a1, h1) = spawn_stub_worker_dying_mid_round(100);
    let cfg = dist_cfg(&[a0, a1], 100, 40, 1e-4);
    let (tr, te) = harness::load_dataset(&cfg).unwrap();

    let t0 = std::time::Instant::now();
    let err = train_distributed(&cfg, &tr, &te).unwrap_err().to_string();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(20),
        "coordinator hung on dead workers: {:?}",
        t0.elapsed()
    );
    assert!(err.contains("worker_lost"), "{err}");
    assert!(err.contains("all 2 workers lost"), "{err}");

    h0.join().unwrap();
    h1.join().unwrap();
}

/// The respawn path needs a real child process to kill and replace, so
/// this test drives the actual binary: spawn-local 2-worker train with an
/// injected exit in worker 1 and `--worker-retries 2`. The coordinator
/// must respawn the worker (same shard, clean environment) rather than
/// re-shard, and the run completes.
#[test]
fn respawn_recovers_a_killed_local_worker() {
    // target dir of the test binary: target/debug/deps/... → target/debug
    let mut bin = std::env::current_exe().unwrap();
    bin.pop();
    if bin.ends_with("deps") {
        bin.pop();
    }
    let bin = bin.join("dcsvm");
    if !bin.exists() {
        panic!("dcsvm binary not built at {}", bin.display());
    }
    let dir = std::env::temp_dir().join("dcsvm_respawn_e2e");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let out = std::process::Command::new(&bin)
        .args([
            "train",
            "--distributed",
            "true",
            "--workers",
            "2",
            "--rounds",
            "2",
            "--dataset",
            "covtype-like",
            "--n-train",
            "200",
            "--n-test",
            "60",
            "--gamma",
            "16",
            "--c",
            "4",
            "--backend",
            "native",
            "--threads",
            "2",
            "--worker-retries",
            "2",
        ])
        .env("DCSVM_FAULT", "worker:1,round:2,kind:exit")
        .env("DCSVM_RESULTS_DIR", dir.to_str().unwrap())
        .output()
        .expect("spawn dcsvm train --distributed");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(out.status.success(), "respawn run failed:\n{text}");

    let results = std::fs::read_to_string(dir.join("results.jsonl")).unwrap();
    let last = results.lines().last().expect("one result line");
    let outcome = Json::parse(last).unwrap();
    let outcome = outcome.get("outcome");
    assert!(
        outcome.get("respawns").as_f64().unwrap() >= 1.0,
        "worker must be respawned, not re-sharded:\n{text}"
    );
    assert!(outcome.get("workers_lost").as_f64().unwrap() >= 1.0, "{text}");
    assert_eq!(
        outcome.get("resharded_rows").as_f64(),
        Some(0.0),
        "respawn keeps the shard in place:\n{text}"
    );
    assert!(outcome.get("rounds_replayed").as_f64().unwrap() >= 1.0, "{text}");
    assert!(outcome.get("accuracy").as_f64().unwrap() > 0.5, "{text}");
    assert!(text.contains("respawned"), "stderr should log the respawn:\n{text}");
}
