//! Distributed parallel block minimization, end to end over real sockets:
//! a loopback protocol round-trip, the 2-worker vs single-process
//! equivalence gate (same dual objective, same accuracy, α summaries only
//! on the wire), and the worker-loss abort path.
//!
//! Workers run as in-process threads on ephemeral listeners
//! (`run_worker` serves one session per process in production; the
//! spawn-local child-process path is exercised by `cli_roundtrip.rs`,
//! which drives the real binary).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use dcsvm::cache::KernelContext;
use dcsvm::config::RunConfig;
use dcsvm::distributed::{ids_json, run_worker, train_distributed, Hello, WorkerOptions};
use dcsvm::harness;
use dcsvm::predict::SvmModel;
use dcsvm::solver::{SmoConfig, SmoSolver};
use dcsvm::util::json::Json;
use dcsvm::util::wire::{self, Frame, TcpCodec};

/// A real worker on an ephemeral loopback port, serving one session.
fn spawn_worker() -> (String, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = WorkerOptions { threads: 2, cache_mb: 64, backend: "native".into() };
    let h = std::thread::spawn(move || run_worker(listener, &opts).unwrap());
    (addr, h)
}

fn dist_cfg(addrs: &[String], n_train: usize, n_test: usize, eps: f64) -> RunConfig {
    RunConfig {
        dataset: "covtype-like".into(),
        n_train: Some(n_train),
        n_test: Some(n_test),
        gamma: 16.0,
        c: 4.0,
        eps,
        backend: "native".into(),
        distributed: true,
        rounds: 2,
        workers_addr: Some(addrs.join(",")),
        ..RunConfig::default()
    }
}

fn read_json(codec: &mut TcpCodec) -> Json {
    loop {
        match codec.read_frame().unwrap() {
            Frame::Line(l) => {
                let t = l.trim();
                if t.is_empty() {
                    continue;
                }
                return Json::parse(t).unwrap();
            }
            Frame::Idle => continue,
            other => panic!("unexpected frame: {other:?}"),
        }
    }
}

/// Loopback unit round-trip: hello → shard → round → structured protocol
/// error → shutdown, one worker, manual coordinator side.
#[test]
fn loopback_worker_session_roundtrip() {
    let (addr, h) = spawn_worker();
    let mut codec = wire::tcp_codec(TcpStream::connect(&addr).unwrap()).unwrap();

    let hello = Hello {
        dataset: "covtype-like".into(),
        n_train: 120,
        n_test: 40,
        seed: 0,
        kernel: "rbf".into(),
        gamma: 16.0,
        eta: 0.0,
        c: 4.0,
        eps: 1e-3,
    };
    codec.write_json(&Json::obj(vec![("hello", hello.to_json())])).unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    assert_eq!(r.get("n").as_usize(), Some(120), "{r}");

    let shard: Vec<usize> = (0..120).step_by(2).collect();
    codec.write_json(&Json::obj(vec![("shard", ids_json(&shard))])).unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    assert_eq!(r.get("rows").as_usize(), Some(60), "{r}");

    // Round 1: no external summaries yet — a plain block solve.
    codec
        .write_json(&Json::obj(vec![
            ("round", Json::from(1usize)),
            ("ext_ids", Json::Arr(vec![])),
            ("ext_alpha", Json::Arr(vec![])),
        ]))
        .unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("round").as_usize(), Some(1), "{r}");
    let ids = r.get("ids").as_arr().unwrap();
    let al = r.get("alpha").as_arr().unwrap();
    assert_eq!(ids.len(), al.len());
    assert!(!ids.is_empty(), "a solved block has support vectors");
    for v in ids {
        let i = v.as_usize().unwrap();
        assert!(shard.contains(&i), "summary id {i} outside the shard");
    }
    assert!(r.get("objective").as_f64().is_some(), "{r}");
    assert!(r.get("values_computed").as_f64().unwrap() > 0.0, "{r}");

    // Mismatched ext arrays → structured protocol error, session continues.
    codec
        .write_json(&Json::obj(vec![
            ("round", Json::from(2usize)),
            ("ext_ids", ids_json(&[0usize])),
            ("ext_alpha", Json::Arr(vec![])),
        ]))
        .unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("error").get("code").as_str(), Some("protocol"), "{r}");

    codec.write_json(&Json::obj(vec![("shutdown", Json::from(true))])).unwrap();
    let r = read_json(&mut codec);
    assert_eq!(r.get("ok").as_bool(), Some(true), "{r}");
    drop(codec);
    h.join().unwrap();
}

/// The equivalence gate: a 2-worker distributed run must land on the same
/// ε-KKT solution as a single-process solve — same dual objective (1e-6
/// relative), same test accuracy — while moving only α summaries over the
/// wire (orders of magnitude below one serialized kernel block).
#[test]
fn two_worker_run_matches_single_process() {
    let (a0, h0) = spawn_worker();
    let (a1, h1) = spawn_worker();
    let cfg = dist_cfg(&[a0, a1], 300, 100, 1e-8);
    let (tr, te) = harness::load_dataset(&cfg).unwrap();

    let out = train_distributed(&cfg, &tr, &te).unwrap();
    h0.join().unwrap();
    h1.join().unwrap();

    // Single-process comparator at the same final tolerance.
    let kind = cfg.kernel_kind().unwrap();
    let kernel = harness::make_kernel(kind, "native", tr.dim).unwrap();
    let ctx = KernelContext::new(&tr, kernel.as_ref(), 64 << 20).with_threads(2);
    let res = SmoSolver::new(
        ctx.view_full(),
        SmoConfig { c: cfg.c, eps: cfg.eps, ..SmoConfig::default() },
    )
    .solve();
    let model = SvmModel::from_ctx_alpha(&ctx, &res.alpha);
    let te_ctx = KernelContext::new(&te, kernel.as_ref(), 1 << 20).with_threads(2);
    let acc_single = model.accuracy_ctx(&te_ctx);

    let (od, os) = (out.objective.unwrap(), res.objective);
    assert!(
        (od - os).abs() <= 1e-6 * (1.0 + os.abs()),
        "distributed objective {od} vs single-process {os}"
    );
    assert_eq!(
        out.accuracy, acc_single,
        "distributed and single-process models must classify identically"
    );

    // Communication efficiency: the whole run's wire traffic stays far
    // below ONE serialized kernel block (n² f32 entries).
    let comm = out.comm_bytes.expect("comm_bytes recorded");
    let kernel_block_bytes = (tr.len() * tr.len() * 4) as u64;
    assert!(comm > 0);
    assert!(
        comm < kernel_block_bytes / 4,
        "comm_bytes {comm} not ≪ kernel block {kernel_block_bytes}"
    );
    assert_eq!(out.rounds, Some(2));
    assert!(out.worker_values_computed.expect("worker values recorded") > 0);
    assert_eq!(out.algo, "Distributed");
    assert!(out.note.contains("workers=2"), "note: {}", out.note);
    assert!(out.note.contains("spawned=false"), "note: {}", out.note);
}

/// A protocol-fluent stub that dies between rounds: answers hello and
/// shard, reads round 1, then drops the connection without replying.
fn spawn_stub_worker_dying_mid_round(n: usize) -> (String, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut write = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // hello
        writeln!(
            write,
            "{}",
            Json::obj(vec![("ok", Json::from(true)), ("n", Json::from(n))])
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap(); // shard
        writeln!(
            write,
            "{}",
            Json::obj(vec![("ok", Json::from(true)), ("rows", Json::from(1usize))])
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap(); // round 1 — die without replying
    });
    (addr, h)
}

/// Losing a worker mid-round must abort the run with a structured
/// `worker_lost` error promptly (within read-poll ticks, not a hang) and
/// release the surviving worker cleanly.
#[test]
fn lost_worker_aborts_the_run_with_a_structured_error() {
    let (a0, h0) = spawn_worker();
    let (a1, h1) = spawn_stub_worker_dying_mid_round(100);
    let cfg = dist_cfg(&[a0, a1], 100, 40, 1e-4);
    let (tr, te) = harness::load_dataset(&cfg).unwrap();

    let t0 = std::time::Instant::now();
    let err = train_distributed(&cfg, &tr, &te).unwrap_err().to_string();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(20),
        "coordinator hung on a dead worker: {:?}",
        t0.elapsed()
    );
    assert!(err.contains("worker_lost"), "{err}");
    assert!(err.contains("worker 1"), "{err}");

    // The surviving worker's session ends on coordinator EOF; the stub
    // already exited. Neither thread leaks.
    h0.join().unwrap();
    h1.join().unwrap();
}
