//! Cross-module property tests: the mathematical invariants that make
//! DC-SVM *exact* (not approximate), checked over randomized instances with
//! the in-repo property harness (seeded; failures print a replay seed).

use dcsvm::cache::KernelContext;
use dcsvm::data::synthetic::{covtype_like, generate, ijcnn1_like, MixtureSpec};
use dcsvm::data::Dataset;
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::kmeans::two_step_partition;
use dcsvm::metrics::objective_of;
use dcsvm::predict::SvmModel;
use dcsvm::prop_assert;
use dcsvm::solver::{solve_svm, SmoConfig, SmoSolver};
use dcsvm::util::prng::Pcg64;
use dcsvm::util::proptest::check;

fn random_instance(rng: &mut Pcg64, max_n: usize) -> (Dataset, KernelKind, f64) {
    let n = 40 + rng.below(max_n.saturating_sub(40).max(1));
    let spec: MixtureSpec = if rng.next_f64() < 0.5 { covtype_like() } else { ijcnn1_like() };
    let ds = generate(&spec, n, rng);
    let kind = if rng.next_f64() < 0.75 {
        KernelKind::Rbf { gamma: (0.5 + 30.0 * rng.next_f64()) as f32 }
    } else {
        KernelKind::Poly { gamma: (0.1 + rng.next_f64()) as f32, eta: 0.0 }
    };
    let c = 0.5 + 8.0 * rng.next_f64();
    (ds, kind, c)
}

/// Warm starting from ANY feasible point must not worsen the reached
/// objective, and from the optimum must converge almost immediately.
#[test]
fn prop_warm_start_never_worse() {
    check("warm-start-never-worse", 6, |rng| {
        let (ds, kind, c) = random_instance(rng, 160);
        let kern = NativeKernel::new(kind);
        let cfg = SmoConfig { c, eps: 1e-7, ..Default::default() };
        let ctx = KernelContext::new(&ds, &kern, 64 << 20);
        let cold = SmoSolver::new(ctx.view_full(), cfg.clone()).solve();
        // Feasible warm start: perturbation of the optimum (the DC-SVM use
        // case — ᾱ is close to α*). A *fully random* start accumulates f32
        // kernel-row drift in the maintained gradient over the long
        // trajectory, which bounds achievable relative accuracy ~1e-3; the
        // near-optimal regime is what warm starting is for.
        let a0: Vec<f64> = cold
            .alpha
            .iter()
            .map(|&a| (a + 0.1 * c * (rng.next_f64() - 0.5)).clamp(0.0, c))
            .collect();
        let warm = SmoSolver::new(ctx.view_full(), cfg.clone()).solve_warm(Some(&a0), &mut |_| {});
        prop_assert!(
            (warm.objective - cold.objective).abs() < 1e-4 * (1.0 + cold.objective.abs()),
            "warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
        // Warm start from the reached optimum: never more work than cold.
        // (On ill-conditioned instances the recomputed exact warm-start
        // gradient exposes residual f32 drift, so "instant" convergence is
        // not guaranteed — but it can never be *worse* than from zero.)
        let at_opt =
            SmoSolver::new(ctx.view_full(), cfg).solve_warm(Some(&cold.alpha), &mut |_| {});
        prop_assert!(
            at_opt.iterations <= cold.iterations + 4,
            "restart from optimum took {} iters (cold {})",
            at_opt.iterations,
            cold.iterations
        );
        Ok(())
    });
}

/// DC-SVM must land on the same optimum as the direct solver for any
/// random instance/schedule, and its early model must beat chance.
#[test]
fn prop_dcsvm_exactness_random_schedules() {
    check("dcsvm-exactness", 5, |rng| {
        let (ds, kind, c) = random_instance(rng, 300);
        let kern = NativeKernel::new(kind);
        let levels = 1 + rng.below(3);
        let cfg = DcSvmConfig {
            kind,
            c,
            levels,
            k_base: 2 + rng.below(3),
            sample_m: 24 + rng.below(64),
            eps_final: 1e-6,
            adaptive: rng.next_f64() < 0.5,
            refine: rng.next_f64() < 0.5,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let dc = train(&ds, &kern, &cfg);
        let direct = solve_svm(&ds, &kern, SmoConfig { c, eps: 1e-6, ..Default::default() });
        prop_assert!(
            (dc.objective.unwrap() - direct.objective).abs()
                < 1e-3 * (1.0 + direct.objective.abs()),
            "levels={levels}: dc {} direct {}",
            dc.objective.unwrap(),
            direct.objective
        );
        Ok(())
    });
}

/// The concatenated subproblem solution must always be feasible and its
/// objective must sit between the optimum and 0 (the α=0 objective).
#[test]
fn prop_divide_step_objective_sandwich() {
    check("divide-sandwich", 5, |rng| {
        let (ds, kind, c) = random_instance(rng, 240);
        let kern = NativeKernel::new(kind);
        let k = 2 + rng.below(6);
        let ctx = KernelContext::new(&ds, &kern, 64 << 20);
        let (_, part) = two_step_partition(&ctx, k, 48, None, rng);
        let mut alpha_bar = vec![0f64; ds.len()];
        for members in &part.members {
            if members.is_empty() {
                continue;
            }
            let sub = ds.subset(members, "c");
            let res = solve_svm(&sub, &kern, SmoConfig { c, eps: 1e-7, ..Default::default() });
            for (t, &i) in members.iter().enumerate() {
                alpha_bar[i] = res.alpha[t];
            }
        }
        prop_assert!(
            alpha_bar.iter().all(|&a| (0.0..=c + 1e-12).contains(&a)),
            "infeasible ᾱ"
        );
        let f_bar = objective_of(&ds, &kern, &alpha_bar);
        let star = solve_svm(&ds, &kern, SmoConfig { c, eps: 1e-8, ..Default::default() });
        prop_assert!(
            f_bar >= star.objective - 1e-5 * (1.0 + star.objective.abs()),
            "f(ᾱ)={f_bar} below optimum {}",
            star.objective
        );
        prop_assert!(f_bar <= 1e-9, "f(ᾱ)={f_bar} above f(0)=0");
        Ok(())
    });
}

/// Early-prediction routing must be a function (same input → same cluster)
/// and must agree between single-point and batched paths.
#[test]
fn prop_router_deterministic_and_batch_consistent() {
    check("router-consistency", 6, |rng| {
        let (ds, kind, _) = random_instance(rng, 200);
        let kern = NativeKernel::new(kind);
        let k = 2 + rng.below(5);
        let ctx = KernelContext::new(&ds, &kern, 64 << 20);
        let (router, part) = two_step_partition(&ctx, k, 32, None, rng);
        let norms = ctx.norms();
        let batch = router.assign_rows(&ds.x, norms, &kern);
        prop_assert!(batch == part.assign, "batch assign != training assign");
        for probe in 0..5 {
            let i = rng.below(ds.len());
            let one = router.assign_one(ds.row(i), &kern);
            prop_assert!(
                one == batch[i],
                "probe {probe}: single {} != batch {}",
                one,
                batch[i]
            );
        }
        Ok(())
    });
}

/// Model serialization round-trip must preserve every prediction.
#[test]
fn prop_model_json_roundtrip_preserves_predictions() {
    check("model-json-roundtrip", 5, |rng| {
        let (ds, kind, c) = random_instance(rng, 150);
        let kern = NativeKernel::new(kind);
        let res = solve_svm(&ds, &kern, SmoConfig { c, eps: 1e-4, ..Default::default() });
        let model = SvmModel::from_alpha(&ds, &res.alpha, kind);
        let json = model.to_json().to_string();
        let back = SvmModel::from_json(
            &dcsvm::util::json::Json::parse(&json).expect("parse"),
        )
        .expect("decode");
        let norms = ds.sq_norms();
        let a = model.decision_batch(&ds.x, &norms, &kern);
        let b = back.decision_batch(&ds.x, &norms, &kern);
        for (i, (&u, &v)) in a.iter().zip(&b).enumerate() {
            prop_assert!(
                (u - v).abs() <= 1e-5 * (1.0 + v.abs()),
                "decision[{i}]: {u} vs {v}"
            );
        }
        Ok(())
    });
}

/// Objective consistency: solver-reported objective == recomputed-from-α
/// objective for every algorithm that exposes α.
#[test]
fn prop_reported_objective_matches_alpha() {
    check("objective-consistency", 5, |rng| {
        let (ds, kind, c) = random_instance(rng, 180);
        let kern = NativeKernel::new(kind);
        let res = solve_svm(&ds, &kern, SmoConfig { c, eps: 1e-5, ..Default::default() });
        let recomputed = objective_of(&ds, &kern, &res.alpha);
        prop_assert!(
            (res.objective - recomputed).abs() < 1e-4 * (1.0 + recomputed.abs()),
            "reported {} recomputed {}",
            res.objective,
            recomputed
        );
        Ok(())
    });
}
