//! Docs ↔ code consistency gates. The serve flag table, the stats-field
//! glossary, and the error-object catalogue each have ONE source of truth
//! in the code (`serving::transport`); these tests fail the build when a
//! top-level doc drifts from it.

use dcsvm::distributed::{DIST_FLAGS, WORKER_ERROR_CODES, WORKER_FLAGS};
use dcsvm::serving::transport::{readme_row, ERROR_CODES, SERVE_FLAGS};
use dcsvm::serving::BatchStats;

/// Read a repo-root file (the manifest dir is `rust/`).
fn repo_file(name: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// README's serve flag table must contain the exact row `readme_row`
/// renders for every flag — the same table `dcsvm serve --help` is
/// generated from (`cli_roundtrip.rs` checks that side), so the CLI and
/// README cannot drift apart.
#[test]
fn readme_serve_flag_table_matches_the_cli_table() {
    let readme = repo_file("README.md");
    for f in SERVE_FLAGS {
        let row = readme_row(f);
        assert!(
            readme.contains(&row),
            "README.md serve-flag table is stale; expected the exact row:\n{row}\n\
             (regenerate from serving::transport::SERVE_FLAGS)"
        );
    }
}

/// Every stats field `BatchStats::to_json` emits must be glossed in
/// PROTOCOL.md (backticked, so it renders as a field name).
#[test]
fn protocol_doc_glosses_every_stats_field() {
    let proto = repo_file("PROTOCOL.md");
    let stats = BatchStats::default().to_json(0);
    for key in stats.as_obj().expect("stats json is an object").keys() {
        assert!(
            proto.contains(&format!("`{key}`")),
            "PROTOCOL.md stats glossary is missing `{key}`"
        );
    }
}

/// Every error code the socket transport can return must be catalogued in
/// PROTOCOL.md.
#[test]
fn protocol_doc_catalogues_every_error_code() {
    let proto = repo_file("PROTOCOL.md");
    for code in ERROR_CODES {
        assert!(
            proto.contains(&format!("`{code}`")),
            "PROTOCOL.md error catalogue is missing `{code}`"
        );
    }
}

/// README's worker and distributed-train flag tables must contain the
/// exact rows rendered from the code tables (`dcsvm::distributed`), the
/// same tables `dcsvm worker --help` is generated from.
#[test]
fn readme_worker_and_distributed_flag_tables_match_the_cli_tables() {
    let readme = repo_file("README.md");
    for f in WORKER_FLAGS.iter().chain(DIST_FLAGS) {
        let row = readme_row(f);
        assert!(
            readme.contains(&row),
            "README.md worker/distributed flag table is stale; expected the exact row:\n{row}\n\
             (regenerate from dcsvm::distributed::{{WORKER_FLAGS, DIST_FLAGS}})"
        );
    }
}

/// PROTOCOL.md must document the worker wire protocol: a dedicated
/// section plus every error code a worker session (or a coordinator-side
/// distributed failure) can carry.
#[test]
fn protocol_doc_catalogues_the_worker_wire_protocol() {
    let proto = repo_file("PROTOCOL.md");
    assert!(
        proto.contains("Worker wire protocol"),
        "PROTOCOL.md is missing the \"Worker wire protocol\" section"
    );
    for code in WORKER_ERROR_CODES {
        assert!(
            proto.contains(&format!("`{code}`")),
            "PROTOCOL.md worker error catalogue is missing `{code}`"
        );
    }
}

/// PROTOCOL.md must document the fault-recovery surface: the `reshard`
/// message workers accept during recovery, and the deadline flags the
/// coordinator's detection is built on.
#[test]
fn protocol_doc_covers_recovery_semantics() {
    let proto = repo_file("PROTOCOL.md");
    for needle in ["`reshard`", "`--round-timeout`", "`--worker-retries`", "`--request-timeout`"]
    {
        assert!(
            proto.contains(needle),
            "PROTOCOL.md recovery/timeout documentation is missing {needle}"
        );
    }
}
