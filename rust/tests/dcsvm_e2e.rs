//! Integration: multilevel DC-SVM end-to-end against the direct solver,
//! Lemma-1 / Theorem-1 invariants, early prediction floors, and the
//! cross-phase kernel-cache reuse regression.

use dcsvm::cache::KernelContext;
use dcsvm::data::synthetic::{covtype_like, generate, generate_split, webspam_like};
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::kmeans::{off_diagonal_mass, two_step_partition, Partition};
use dcsvm::metrics::objective_of;
use dcsvm::predict::SvmModel;
use dcsvm::solver::{solve_svm, SmoConfig, SmoSolver};
use dcsvm::util::prng::Pcg64;

fn kind() -> KernelKind {
    KernelKind::Rbf { gamma: 16.0 }
}

/// Lemma 1: the concatenation of subproblem optima is the optimum of the
/// block-diagonal-kernel problem; equivalently, per-cluster solves of the
/// full problem restricted to clusters are KKT-optimal for K̄.
#[test]
fn lemma1_blockdiag_optimality() {
    let mut rng = Pcg64::new(100);
    let ds = generate(&covtype_like(), 240, &mut rng);
    let kern = NativeKernel::new(kind());
    let ctx = KernelContext::new(&ds, &kern, 64 << 20);
    let c = 2.0;
    let (_, part) = two_step_partition(&ctx, 4, 60, None, &mut rng);

    // Solve each cluster subproblem exactly.
    let mut alpha_bar = vec![0f64; ds.len()];
    for members in &part.members {
        if members.is_empty() {
            continue;
        }
        let sub = ds.subset(members, "c");
        let res = solve_svm(&sub, &kern, SmoConfig { c, eps: 1e-8, ..Default::default() });
        for (t, &i) in members.iter().enumerate() {
            alpha_bar[i] = res.alpha[t];
        }
    }

    // KKT of the block-diagonal problem: within each cluster, gradient of
    // the *cluster* subproblem satisfies the box optimality conditions.
    for members in &part.members {
        if members.is_empty() {
            continue;
        }
        let sub = ds.subset(members, "c");
        let a: Vec<f64> = members.iter().map(|&i| alpha_bar[i]).collect();
        let q = dcsvm::solver::objective::dense_q(&sub, &kern);
        let m = sub.len();
        for i in 0..m {
            let g: f64 = (0..m).map(|j| q[i * m + j] * a[j]).sum::<f64>() - 1.0;
            let viol = dcsvm::solver::objective::projected_violation(a[i], g, c);
            assert!(viol < 1e-6, "cluster KKT violation {viol}");
        }
    }
}

/// Theorem 1: 0 <= f(ᾱ) − f(α*) <= ½ C² D(π).
#[test]
fn theorem1_bound_holds() {
    let mut rng = Pcg64::new(101);
    let ds = generate(&covtype_like(), 300, &mut rng);
    let kern = NativeKernel::new(kind());
    let ctx = KernelContext::new(&ds, &kern, 64 << 20);
    let c = 1.0;
    for k in [2usize, 4, 8] {
        let (_, part) = two_step_partition(&ctx, k, 80, None, &mut rng);
        let mut alpha_bar = vec![0f64; ds.len()];
        for members in &part.members {
            if members.is_empty() {
                continue;
            }
            // Subset views of the shared context: the divide-phase solve
            // path the production driver uses.
            let res = SmoSolver::new(
                ctx.view(members),
                SmoConfig { c, eps: 1e-8, ..Default::default() },
            )
            .solve();
            for (t, &i) in members.iter().enumerate() {
                alpha_bar[i] = res.alpha[t];
            }
        }
        let f_bar = objective_of(&ds, &kern, &alpha_bar);
        let star = solve_svm(&ds, &kern, SmoConfig { c, eps: 1e-8, ..Default::default() });
        let gap = f_bar - star.objective;
        let bound = 0.5 * c * c * off_diagonal_mass(&ctx, &part.assign);
        assert!(gap >= -1e-5, "k={k}: f(ᾱ) below optimum?! gap={gap}");
        assert!(
            gap <= bound + 1e-6,
            "k={k}: Theorem-1 bound violated: gap {gap} > bound {bound}"
        );
    }
}

/// Kernel-kmeans partitions must beat random partitions in the actual
/// objective gap (Figure 1's message).
#[test]
fn kernel_partition_tightens_gap_vs_random() {
    let mut rng = Pcg64::new(102);
    let ds = generate(&covtype_like(), 300, &mut rng);
    let kern = NativeKernel::new(kind());
    let ctx = KernelContext::new(&ds, &kern, 64 << 20);
    let c = 1.0;
    let solve_part = |part: &Partition| -> f64 {
        let mut alpha = vec![0f64; ds.len()];
        for members in &part.members {
            if members.is_empty() {
                continue;
            }
            let res = SmoSolver::new(
                ctx.view(members),
                SmoConfig { c, eps: 1e-7, ..Default::default() },
            )
            .solve();
            for (t, &i) in members.iter().enumerate() {
                alpha[i] = res.alpha[t];
            }
        }
        objective_of(&ds, &kern, &alpha)
    };
    let (_, kpart) = two_step_partition(&ctx, 8, 80, None, &mut rng);
    let rpart = Partition::random(ds.len(), 8, &mut rng);
    let f_k = solve_part(&kpart);
    let f_r = solve_part(&rpart);
    let star = solve_svm(&ds, &kern, SmoConfig { c, eps: 1e-8, ..Default::default() });
    let gap_k = f_k - star.objective;
    let gap_r = f_r - star.objective;
    assert!(
        gap_k < gap_r,
        "kernel partition gap {gap_k} not below random {gap_r}"
    );
}

/// Full multilevel pipeline on two datasets: exact optimum + decent early
/// accuracy + no more final iterations than cold.
#[test]
fn multilevel_pipeline_two_datasets() {
    for (spec, seed) in [(covtype_like(), 1u64), (webspam_like(), 2u64)] {
        let (tr, te) = generate_split(&spec, 700, 200, seed);
        let kern = NativeKernel::new(kind());
        let cfg = DcSvmConfig {
            kind: kind(),
            c: 4.0,
            levels: 3,
            k_base: 4,
            sample_m: 96,
            eps_final: 1e-5,
            keep_level_alphas: true,
            ..Default::default()
        };
        let dc = train(&tr, &kern, &cfg);
        let cold = solve_svm(
            &tr,
            &kern,
            SmoConfig { c: 4.0, eps: 1e-5, ..Default::default() },
        );
        let rel = (dc.objective.unwrap() - cold.objective).abs()
            / (1.0 + cold.objective.abs());
        assert!(rel < 1e-3, "{}: rel {rel}", spec.name);
        assert!(
            dc.final_iterations <= cold.iterations,
            "{}: warm {} > cold {}",
            spec.name,
            dc.final_iterations,
            cold.iterations
        );
        let em = dc.early_model.as_ref().unwrap();
        let acc = em.accuracy(&te, &kern);
        assert!(acc > 0.70, "{}: early acc {acc}", spec.name);
    }
}

/// SV identification (Figure 2): divide levels already recover most of the
/// final SV set, with high precision.
#[test]
fn lower_levels_identify_svs() {
    let (tr, _) = generate_split(&covtype_like(), 600, 100, 5);
    let kern = NativeKernel::new(kind());
    let cfg = DcSvmConfig {
        kind: kind(),
        c: 4.0,
        levels: 3,
        sample_m: 96,
        eps_final: 1e-6,
        keep_level_alphas: true,
        ..Default::default()
    };
    let dc = train(&tr, &kern, &cfg);
    let final_alpha = &dc.alpha;
    let mut last_recall = 0.0;
    for ls in &dc.levels {
        let a = ls.alpha.as_ref().unwrap();
        let (prec, rec) = dcsvm::metrics::sv_precision_recall(a, final_alpha);
        assert!(rec > 0.6, "level {} recall {rec}", ls.level);
        assert!(prec > 0.6, "level {} precision {prec}", ls.level);
        last_recall = rec;
    }
    assert!(last_recall > 0.8, "top divide level recall {last_recall}");
}

/// Regression (ISSUE satellite): the conquer solve must start with the
/// divide/refine phases' kernel values already resident in the run's
/// shared context — its full rows are *stitched* from the cached cluster
/// segments — so it evaluates strictly fewer kernel entries than the
/// *same* warm-started solve on a cold cache (the old per-solve
/// cold-cache path).
#[test]
fn shared_context_prewarms_conquer_solve() {
    let (tr, _) = generate_split(&covtype_like(), 700, 100, 9);
    let kern = NativeKernel::new(kind());
    let cfg = DcSvmConfig {
        kind: kind(),
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 96,
        eps_sub: 1e-3,
        eps_final: 1e-5,
        keep_level_alphas: true,
        ..Default::default()
    };
    let dc = train(&tr, &kern, &cfg);
    assert!(!dc.early_stopped);
    let warm0 = dc.pre_final_alpha.clone().expect("kept with keep_level_alphas");

    // Replay the exact final solve on a fresh (cold) context — identical
    // math (same warm start, same tolerances), different cache state.
    let cold_ctx = KernelContext::new(&tr, &kern, 256 << 20);
    let cold = SmoSolver::new(
        cold_ctx.view_full(),
        SmoConfig { c: 4.0, eps: 1e-5, ..Default::default() },
    )
    .solve_warm(Some(&warm0), &mut |_| {});

    // Identical trajectory...
    assert_eq!(
        dc.final_iterations, cold.iterations,
        "cache state must not change the solve trajectory"
    );
    // ...but the shared-context conquer solve stitched divide/refine
    // segment values instead of recomputing them.
    assert!(cold.values_computed > 0, "cold final solve computed no values");
    assert!(
        dc.final_values_computed < cold.values_computed,
        "shared-context final solve computed {} kernel values, cold-cache {}",
        dc.final_values_computed,
        cold.values_computed
    );
    assert!(dc.stitched_values > 0, "conquer solve never stitched a segment");
    // The run saw real cross-phase reuse overall.
    assert!(dc.cache_hits > 0);
}

/// Acceptance (ISSUE): warm prefetch groups stitchable rows by
/// segment-coverage pattern, so it performs strictly fewer gathered
/// dispatches than rows stitched — while every assembled row stays
/// bit-identical to the per-row stitching path.
#[test]
fn warm_prefetch_groups_stitch_dispatches() {
    let mut rng = Pcg64::new(150);
    let ds = generate(&covtype_like(), 240, &mut rng);
    let kern = NativeKernel::new(kind());
    let grouped = KernelContext::new(&ds, &kern, 64 << 20);
    let perrow = KernelContext::new(&ds, &kern, 64 << 20);
    let n = ds.len();
    // Divide-phase shape: a cluster partition whose segment rows are warm
    // (each row holds its own cluster's partial entry), then a batched
    // warm prefetch over every row — the conquer solve's prewarm pattern.
    let k = 4usize;
    for ctx in [&grouped, &perrow] {
        for c in 0..k {
            let members: Vec<usize> = (0..n).filter(|i| i % k == c).collect();
            let seg = ctx.register_segment(&members);
            assert_eq!(ctx.compute_segment_rows(&seg, &members), members.len());
        }
    }
    let all: Vec<usize> = (0..n).collect();
    assert_eq!(grouped.compute_rows(&all), n);
    for &p in &all {
        perrow.row(p); // the old path: one gathered dispatch per row
    }
    for &p in &all {
        let a = grouped.row(p);
        let b = perrow.row(p);
        for j in 0..n {
            assert_eq!(a[j].to_bits(), b[j].to_bits(), "row {p} col {j}");
        }
    }
    let gv = grouped.value_stats();
    let pv = perrow.value_stats();
    assert_eq!(gv.stitched_rows, n as u64);
    assert_eq!(gv.stitch_groups, k as u64, "one dispatch per coverage pattern");
    assert!(
        gv.stitch_groups < gv.stitched_rows,
        "grouping did not reduce gathered dispatches: {} vs {} rows",
        gv.stitch_groups,
        gv.stitched_rows
    );
    assert_eq!(pv.stitch_groups, pv.stitched_rows, "per-row pays 1 dispatch/row");
    assert_eq!(gv.values_computed, pv.values_computed, "grouping changed kernel work");
}

/// Acceptance (ISSUE): the whole pipeline — divide, refine, conquer,
/// prediction — is bit-identical between single- and multi-threaded
/// dispatch: same final α, same test decisions.
#[test]
fn multithreaded_training_bit_identical_end_to_end() {
    let (tr, te) = generate_split(&covtype_like(), 450, 120, 31);
    let kern = NativeKernel::new(kind());
    let mut cfg = DcSvmConfig {
        kind: kind(),
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        eps_final: 1e-5,
        ..Default::default()
    };
    cfg.threads = 1;
    let single = train(&tr, &kern, &cfg);
    cfg.threads = 4;
    let multi = train(&tr, &kern, &cfg);
    assert_eq!(single.alpha, multi.alpha, "thread count changed the final α");
    assert_eq!(single.final_iterations, multi.final_iterations);
    let m1 = SvmModel::from_alpha(&tr, &single.alpha, kind());
    let m4 = SvmModel::from_alpha(&tr, &multi.alpha, kind());
    let norms = te.sq_norms();
    let d1 = m1.decision_batch(&te.x, &norms, &kern);
    let d4 = m4.decision_batch_par(&te.x, &norms, &kern, 4);
    for (i, (a, b)) in d1.iter().zip(&d4).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "decision {i} differs across threads");
    }
}

/// Acceptance regression (ISSUE): with cluster-aligned segments the divide
/// phase computes ≥ 2× fewer kernel values at k ≥ 4 than the full-row
/// baseline (`segment_views = false`), with bit-identical final α and
/// bit-identical test decisions.
#[test]
fn divide_phase_segment_savings_at_k4() {
    let (tr, te) = generate_split(&covtype_like(), 800, 150, 11);
    let kern = NativeKernel::new(kind());
    let mut cfg = DcSvmConfig {
        kind: kind(),
        c: 4.0,
        levels: 2, // k = 16 then k = 4 — both levels ≥ 4 clusters
        k_base: 4,
        sample_m: 96,
        eps_sub: 1e-3,
        eps_final: 1e-5,
        ..Default::default()
    };
    cfg.segment_views = true;
    let seg = train(&tr, &kern, &cfg);
    cfg.segment_views = false;
    let full = train(&tr, &kern, &cfg);

    // Bit-identical solution and decisions: segment rows hold the exact
    // same kernel values full rows do, so the solver trajectory is
    // unchanged.
    assert_eq!(seg.alpha, full.alpha, "segmented divide changed the final α");
    assert_eq!(seg.final_iterations, full.final_iterations);
    let m_seg = SvmModel::from_alpha(&tr, &seg.alpha, kind());
    let m_full = SvmModel::from_alpha(&tr, &full.alpha, kind());
    let norms = te.sq_norms();
    let dv_seg = m_seg.decision_batch(&te.x, &norms, &kern);
    let dv_full = m_full.decision_batch(&te.x, &norms, &kern);
    assert_eq!(dv_seg, dv_full, "test decisions differ");

    // ≥ 2× divide-phase kernel-value savings (counter-based).
    assert!(seg.segment_rows_computed > 0, "no segment rows computed");
    assert_eq!(full.segment_rows_computed, 0, "baseline must not use segments");
    assert!(
        full.divide_values_computed >= 2 * seg.divide_values_computed,
        "divide-phase values: segmented {} vs full-row {} (< 2× saving)",
        seg.divide_values_computed,
        full.divide_values_computed
    );
}

/// Acceptance (ISSUE satellite): a deep run whose live level's gathered
/// working set alone exceeds `registry_cap_bytes` must NOT thrash
/// re-gathers. The per-level generation floor exempts the live level from
/// the byte-cap GC — only earlier generations are evicted — so
/// `segment_regathers` stays 0 and the solution is bit-identical to the
/// uncapped run, while the capped peak stays well below the uncapped one.
#[test]
fn tight_registry_cap_never_regathers_live_level() {
    let (tr, _) = generate_split(&covtype_like(), 700, 100, 23);
    let kern = NativeKernel::new(kind());
    let mut cfg = DcSvmConfig {
        kind: kind(),
        c: 4.0,
        levels: 3,
        k_base: 4,
        sample_m: 64,
        eps_sub: 1e-3,
        eps_final: 1e-5,
        ..Default::default()
    };
    let full = train(&tr, &kern, &cfg);
    assert_eq!(full.segment_regathers, 0, "uncapped run re-gathered?!");
    assert!(full.registry_peak_bytes > 0);

    // 32 KiB is far below even one level's gathered working set
    // (~n·(dim+1)·4 ≈ 154 KiB here), so every generation boundary evicts
    // the previous level's segments — but never the live level's.
    cfg.registry_cap_bytes = 32 << 10;
    let capped = train(&tr, &kern, &cfg);
    assert_eq!(
        capped.segment_regathers, 0,
        "tight registry cap re-gathered the live level {} times",
        capped.segment_regathers
    );
    assert_eq!(full.alpha, capped.alpha, "registry GC changed the final α");
    assert_eq!(full.final_iterations, capped.final_iterations);
    assert!(
        capped.registry_peak_bytes < full.registry_peak_bytes,
        "cap never evicted anything: capped peak {} vs uncapped {}",
        capped.registry_peak_bytes,
        full.registry_peak_bytes
    );
}

/// Acceptance (ISSUE): int8-quantized routing (`--quant-route`) on the
/// smoke dataset. Training with quantization routes every kmeans
/// assignment through the int8 shadows (counted by `quantized_values`)
/// yet still reaches the same global optimum (the conquer solve is exact
/// either way); early-prediction label flips between the f32 router and
/// its quantized twin stay under the decision-flip gate.
#[test]
fn quant_route_early_prediction_flips_bounded() {
    let (tr, te) = generate_split(&covtype_like(), 600, 150, 17);
    let kern = NativeKernel::new(kind());
    let mut cfg = DcSvmConfig {
        kind: kind(),
        c: 4.0,
        levels: 2,
        k_base: 4,
        sample_m: 64,
        eps_final: 1e-5,
        ..Default::default()
    };
    let exact = train(&tr, &kern, &cfg);
    cfg.quant_route = true;
    let quant = train(&tr, &kern, &cfg);

    // The exact run routes nothing through int8; the quant run routes
    // every assignment pass through it.
    assert_eq!(exact.quantized_values, 0, "quantization leaked into an exact run");
    assert!(quant.quantized_values > 0, "quant run never used the int8 shadows");

    // Routing only shapes the divide partition (convergence speed); the
    // final solve is exact in both runs, so the optima coincide.
    let (fo, qo) = (exact.objective.unwrap(), quant.objective.unwrap());
    let rel = (fo - qo).abs() / (1.0 + fo.abs());
    assert!(rel < 1e-3, "quant routing moved the optimum: rel {rel}");

    // Early-prediction decision flips, f32 router vs its quantized twin,
    // on the same trained model: bounded by the gate.
    let em = exact.early_model.as_ref().expect("early model");
    let mut em_q = em.clone();
    em_q.set_quant_route(true);
    assert!(em_q.quant_route() && !em.quant_route());
    let norms = te.sq_norms();
    let p_exact = em.predict_batch_par(&te.x, &norms, &kern, 2);
    let p_quant = em_q.predict_batch_par(&te.x, &norms, &kern, 2);
    let flips = p_exact.iter().zip(&p_quant).filter(|(a, b)| a != b).count();
    let rate = flips as f64 / te.len() as f64;
    assert!(
        rate <= 0.2,
        "quantized routing flipped {flips}/{} early predictions ({rate:.2})",
        te.len()
    );
}
