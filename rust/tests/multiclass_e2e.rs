//! ISSUE co-headline: multi-class OVO DC-SVM over ONE shared
//! [`KernelContext`], locked down end to end —
//!
//! (a) the shared-context trainer is bit-identical (machine coefficients
//!     = α·y, SV blocks, and votes) to the old materialized per-pair path,
//! (b) cross-pair kernel reuse is counter-visible: later pairs compute
//!     strictly fewer kernel entries than the first,
//! (c) the LIBSVM tie-break-to-smaller-class rule holds as a property
//!     over randomized vote tables,
//!
//! plus the `MulticlassDataset` edge cases (empty, single-class,
//! non-contiguous class ids) and the no-per-pair-materialization cost
//! regression that replaced the deleted `pair_view` path.

use dcsvm::data::Dataset;
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::multiclass::{
    build_ovo_model, pair_members, synthetic_multiclass, train_ovo, train_ovo_shared,
    vote_argmax, MulticlassDataset, TrainedPair,
};
use dcsvm::util::prng::Pcg64;

fn kind() -> KernelKind {
    KernelKind::Rbf { gamma: 2.0 }
}

/// threads = 1 so per-pair kernel-value attribution is exact and the
/// materialized baseline sees the identical dispatch budget.
fn cfg1() -> DcSvmConfig {
    DcSvmConfig {
        kind: kind(),
        c: 4.0,
        levels: 1,
        sample_m: 32,
        threads: 1,
        ..Default::default()
    }
}

/// Exactly `per` rows per class, round-robin — removes class-size noise
/// from the counter assertions.
fn balanced_multiclass(classes: usize, per: usize, dim: usize, seed: u64) -> MulticlassDataset {
    let mut rng = Pcg64::new(seed);
    let centers: Vec<f64> = (0..classes * dim).map(|_| rng.range_f64(0.0, 4.0)).collect();
    let mut x = Vec::with_capacity(classes * per * dim);
    let mut labels = Vec::with_capacity(classes * per);
    for i in 0..classes * per {
        let c = i % classes;
        for j in 0..dim {
            x.push((centers[c * dim + j] + 0.35 * rng.next_gaussian()) as f32);
        }
        labels.push(c as u16);
    }
    MulticlassDataset::new(x, labels, dim)
}

fn norms_of(ds: &MulticlassDataset) -> Vec<f32> {
    (0..ds.len())
        .map(|i| ds.row(i).iter().map(|&v| v * v).sum())
        .collect()
}

/// Tentpole (a): training every pair through member views of ONE shared
/// context yields bit-for-bit the ensemble the old path built by
/// materializing each pair into its own `Dataset` + context — same SV
/// blocks, same per-machine coefficients (α·y as stored), same votes and
/// margins on held-out queries.
#[test]
fn shared_context_ovo_bit_identical_to_materialized_pairs() {
    let tr = synthetic_multiclass(4, 400, 4, 21);
    let te = synthetic_multiclass(4, 100, 4, 22);
    let kern = NativeKernel::new(kind());
    let cfg = cfg1();
    let shared = train_ovo_shared(&tr, &kern, &cfg);

    // The pre-PR-8 path: one materialized ±1 Dataset per pair, each with
    // its own cold context, assembled through the same model builder.
    let present = tr.present_classes();
    let mut pairs = Vec::new();
    for (ai, &a) in present.iter().enumerate() {
        for &b in &present[ai + 1..] {
            let (members, labels) = pair_members(&tr, a, b);
            let mut x = Vec::with_capacity(members.len() * tr.dim);
            for &g in &members {
                x.extend_from_slice(tr.row(g));
            }
            let ds = Dataset::new(x, labels.clone(), tr.dim, format!("pair-{a}-{b}"));
            let res = train(&ds, &kern, &cfg);
            pairs.push(TrainedPair { a, b, members, labels, alpha: res.alpha });
        }
    }
    let baseline = build_ovo_model(&tr, kind(), &pairs, &present);

    assert_eq!(shared.model.machines.len(), baseline.machines.len());
    assert_eq!(shared.pair_dispatches, 6);
    for (m, n) in shared.model.machines.iter().zip(&baseline.machines) {
        assert_eq!((m.a, m.b), (n.a, n.b));
        assert_eq!(m.coef_a, n.coef_a, "pair ({},{}): coef_a (α·y) differs", m.a, m.b);
        assert_eq!(m.coef_b, n.coef_b, "pair ({},{}): coef_b (α·y) differs", m.a, m.b);
    }
    assert_eq!(shared.model.class_sv_x, baseline.class_sv_x, "per-class SV blocks differ");
    assert_eq!(shared.model.present, baseline.present);

    let norms = norms_of(&te);
    let got = shared.model.predict_with_margins(&te.x, &norms, &kern);
    let want = baseline.predict_with_margins(&te.x, &norms, &kern);
    assert_eq!(got, want, "votes/margins differ between shared and materialized");
}

/// Tentpole (b): with segment-row stitching on, the columns pair (a, b)
/// computed for class a's rows are copied — not recomputed — by every
/// later pair touching a. Counter-asserted: the LAST pair trained (whose
/// within-class blocks are both fully cached) computes strictly fewer
/// kernel entries than the FIRST (fully cold), at exact attribution
/// (threads = 1) over a perfectly balanced 4-class problem.
#[test]
fn later_pairs_compute_strictly_fewer_kernel_values() {
    let tr = balanced_multiclass(4, 120, 4, 31);
    let kern = NativeKernel::new(kind());
    let res = train_ovo_shared(&tr, &kern, &cfg1());
    assert!(res.pair_values_exact, "threads=1 must attribute values exactly");
    assert_eq!(res.pair_values.len(), 6, "4·3/2 pairs");

    let (fa, fb, first) = res.pair_values[0];
    let (la, lb, last) = *res.pair_values.last().unwrap();
    assert_eq!((fa, fb), (0, 1));
    assert_eq!((la, lb), (2, 3));
    assert!(first > 0, "first pair computed nothing");
    assert!(
        last < first,
        "pair ({la},{lb}) computed {last} kernel values — not strictly fewer \
         than pair ({fa},{fb})'s {first}: cross-pair reuse is broken"
    );
    // The reuse mechanism itself left tracks: stitched values were copied
    // out of earlier pairs' cached columns.
    assert!(
        res.value_stats.values_stitched > 0,
        "no kernel value was ever stitched from an earlier pair's cache"
    );
}

/// Satellite: `pair_members` is bookkeeping only — the shared-context run
/// must be strictly cheaper in total kernel values than solving each pair
/// as its own freshly materialized 2-class problem (the deleted
/// `pair_view` path's cost shape: every pair pays a cold cache).
#[test]
fn shared_context_beats_per_pair_materialization_on_kernel_values() {
    let tr = balanced_multiclass(3, 110, 4, 41);
    let kern = NativeKernel::new(kind());
    let cfg = cfg1();
    let shared = train_ovo_shared(&tr, &kern, &cfg);

    let present = tr.present_classes();
    let mut independent = 0u64;
    for (ai, &a) in present.iter().enumerate() {
        for &b in &present[ai + 1..] {
            let (members, _) = pair_members(&tr, a, b);
            let mut x = Vec::with_capacity(members.len() * tr.dim);
            let mut labels = Vec::with_capacity(members.len());
            for &g in &members {
                x.extend_from_slice(tr.row(g));
                labels.push(tr.labels[g]);
            }
            let solo = train_ovo_shared(&MulticlassDataset::new(x, labels, tr.dim), &kern, &cfg);
            independent += solo.value_stats.values_computed;
        }
    }
    assert!(
        shared.value_stats.values_computed < independent,
        "one shared context ({}) did not beat per-pair materialization ({})",
        shared.value_stats.values_computed,
        independent
    );
}

/// Tentpole (c): LIBSVM's tie-break rule as a property over randomized
/// vote tables — `vote_argmax` always returns the smallest class id among
/// the maximum-vote present classes, and never a non-present class.
#[test]
fn vote_tie_break_property_over_random_tables() {
    let mut rng = Pcg64::new(77);
    for trial in 0..200 {
        let nc = 2 + rng.below(9); // 2..=10 classes in the table
        let mut present: Vec<u16> = (0..nc as u16).filter(|_| rng.below(2) == 1).collect();
        if present.is_empty() {
            present.push(rng.below(nc) as u16);
        }
        // Small vote range to force frequent ties.
        let votes: Vec<u32> = (0..nc).map(|_| rng.below(4) as u32).collect();
        let got = vote_argmax(&votes, &present);
        let best = present.iter().map(|&c| votes[c as usize]).max().unwrap();
        let want = *present.iter().find(|&&c| votes[c as usize] == best).unwrap();
        assert_eq!(
            got, want,
            "trial {trial}: votes {votes:?} present {present:?} — \
             expected smallest max-vote class"
        );
        assert!(present.contains(&got), "trial {trial}: winner not present");
    }
}

/// Satellite: empty dataset — 0 classes, 0 machines, 0 SVs; prediction
/// degrades to the empty-domain convention (class 0, zero margin).
#[test]
fn empty_dataset_trains_nothing_and_predicts_convention() {
    let ds = MulticlassDataset::new(vec![], vec![], 3);
    assert_eq!(ds.num_classes, 0);
    assert!(ds.is_empty());
    assert!(ds.present_classes().is_empty());
    let kern = NativeKernel::new(kind());
    let model = train_ovo(&ds, &kern, &cfg1());
    assert_eq!(model.machines.len(), 0);
    assert_eq!(model.num_svs(), 0);
    let q = vec![0.5f32, -0.5, 1.0];
    let norms = vec![q.iter().map(|&v| v * v).sum::<f32>()];
    assert_eq!(model.predict_with_margins(&q, &norms, &kern), vec![(0u16, 0.0f32)]);
}

/// Satellite: single class — 0 pairs, and every prediction returns the
/// lone class unconditionally.
#[test]
fn single_class_trains_zero_pairs_and_predicts_lone_class() {
    let base = synthetic_multiclass(1, 60, 3, 51);
    // Relabel to class 2 so the lone class is not the id-0 fallback.
    let ds = MulticlassDataset::new(base.x.clone(), vec![2u16; base.len()], base.dim);
    assert_eq!(ds.present_classes(), vec![2]);
    let kern = NativeKernel::new(kind());
    let model = train_ovo(&ds, &kern, &cfg1());
    assert_eq!(model.machines.len(), 0, "single class trains no machine");
    assert_eq!(model.present, vec![2]);
    let qs = synthetic_multiclass(1, 10, 3, 52);
    let norms = norms_of(&qs);
    for (label, margin) in model.predict_with_margins(&qs.x, &norms, &kern) {
        assert_eq!(label, 2, "lone class must win every vote");
        assert_eq!(margin, 0.0);
    }
}

/// Satellite: non-contiguous class ids {0, 5} — one machine, `present`
/// keeps the raw ids, predictions stay inside {0, 5}, and absent ids
/// never win.
#[test]
fn non_contiguous_class_ids_train_one_machine() {
    let two = balanced_multiclass(2, 60, 3, 61);
    // Map class 1 → 5, leaving ids 1..=4 absent.
    let labels: Vec<u16> = two.labels.iter().map(|&l| if l == 1 { 5 } else { 0 }).collect();
    let ds = MulticlassDataset::new(two.x.clone(), labels, two.dim);
    assert_eq!(ds.num_classes, 6, "num_classes = max id + 1");
    assert_eq!(ds.present_classes(), vec![0, 5]);
    let kern = NativeKernel::new(kind());
    let res = train_ovo_shared(&ds, &kern, &cfg1());
    assert_eq!(res.pair_dispatches, 1, "{{0, 5}} is one pair");
    assert_eq!(res.model.machines.len(), 1);
    assert_eq!((res.model.machines[0].a, res.model.machines[0].b), (0, 5));
    for c in 1..5 {
        assert!(res.model.class_sv_norms[c].is_empty(), "absent class {c} holds SVs");
    }
    let norms = norms_of(&ds);
    for label in res.model.predict_batch(&ds.x, &norms, &kern) {
        assert!(label == 0 || label == 5, "absent class id {label} won a vote");
    }
    // The trained model classifies its own separable blobs well.
    let acc = res.model.accuracy(&ds, &kern);
    assert!(acc > 0.9, "2-class accuracy {acc}");
}
