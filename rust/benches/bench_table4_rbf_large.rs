//! Table 4: RBF kernel on the three largest datasets — webspam / kddcup99 /
//! mnist8m counterparts (reduced n; same solver set as Table 3).

use dcsvm::bench::{banner, fmt_secs, Table};
use dcsvm::config::{Algo, RunConfig};
use dcsvm::harness;

fn main() {
    banner("Table 4", "RBF kernel, large datasets: time(s) / acc(%)");
    let full = std::env::var("FULL").is_ok();
    let settings: &[(&str, usize, usize, f64, f64)] = &[
        ("webspam-like", if full { 6000 } else { 3000 }, 800, 2.0, 8.0),
        ("kddcup99-like", if full { 10000 } else { 4000 }, 1000, 0.5, 256.0),
        ("mnist8m-like", if full { 12000 } else { 4000 }, 1000, 1e-4, 1.0),
    ];

    for &(dataset, ntr, nte, gamma, c) in settings {
        println!("\n--- {dataset}: n={ntr}, γ={gamma}, C={c} ---");
        let mut base = RunConfig::default();
        base.dataset = dataset.into();
        base.n_train = Some(ntr);
        base.n_test = Some(nte);
        base.gamma = gamma;
        base.c = c;
        base.levels = 2;
        base.sample_m = 128;
        base.budget = 48;
        base.cache_mb = 8; // constrained cache: the paper's memory regime
        base.eps = 1e-4;
        let (tr, te) = harness::load_dataset(&base).expect("dataset");

        let mut t = Table::new(&["solver", "time", "acc%"]);
        for algo in Algo::all() {
            let mut cfg = base.clone();
            cfg.algo = algo;
            match harness::run(&cfg, &tr, &te) {
                Ok(out) => t.row(&[
                    out.algo.to_string(),
                    fmt_secs(out.train_s),
                    format!("{:.2}", 100.0 * out.accuracy),
                ]),
                Err(e) => t.row(&[algo.name().to_string(), "ERR".into(), format!("{e}")]),
            }
        }
        t.print();
    }
    println!(
        "\nexpected shape (paper Table 4): same orderings as Table 3; \
         DC-SVM (early) reaches ~exact accuracy orders of magnitude faster."
    );
}
