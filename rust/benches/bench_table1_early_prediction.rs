//! Table 1: prediction with a lower-level (k-cluster) model —
//! naive (eq. 10) vs BCM [Tresp 2000] vs early prediction (eq. 11),
//! accuracy and per-sample test time, on webspam-like and covtype-like.

use std::time::Instant;

use dcsvm::bench::{banner, Table};
use dcsvm::data::synthetic::{covtype_like, generate_split, ijcnn1_like};
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::predict::{BcmModel, SvmModel};

fn main() {
    banner("Table 1", "early prediction (11) vs naive (10) vs BCM — accuracy / ms per test sample");
    let mut t = Table::new(&["dataset", "k", "method", "acc%", "ms/sample"]);

    // ijcnn1-like replaces the paper's webspam slot: webspam-like's geometry
    // saturates (every point an SV) at bench scale, which hides the
    // naive/BCM-vs-early differentiation the table is about.
    for (spec, gamma) in [(ijcnn1_like(), 4.0f32), (covtype_like(), 32.0)] {
        let (tr, te) = generate_split(&spec, 3000, 800, 21);
        let kind = KernelKind::Rbf { gamma };
        let kern = NativeKernel::new(kind);
        let norms = te.sq_norms();

        for &(levels, k_label) in &[(2usize, 16usize), (3, 64)] {
            // single-level DC-SVM with k = 4^levels clusters (paper: 50/100)
            let cfg = DcSvmConfig {
                kind,
                c: 4.0,
                levels,
                k_base: 4,
                sample_m: 128,
                stop_after_level: Some(levels),
                ..Default::default()
            };
            let dc = train(&tr, &kern, &cfg);
            let em = dc.early_model.as_ref().unwrap();

            // naive (10)
            let naive = SvmModel::from_alpha(&tr, &dc.alpha, kind);
            let t0 = Instant::now();
            let preds = naive.predict_batch(&te.x, &norms, &kern);
            let acc10 = dcsvm::metrics::accuracy(&preds, &te.y);
            let ms10 = 1e3 * t0.elapsed().as_secs_f64() / te.len() as f64;

            // BCM
            let bcm = BcmModel::new(em.locals.clone());
            let t0 = Instant::now();
            let accb = bcm.accuracy(&te, &kern);
            let msb = 1e3 * t0.elapsed().as_secs_f64() / te.len() as f64;

            // early (11)
            let t0 = Instant::now();
            let acc11 = em.accuracy(&te, &kern);
            let ms11 = 1e3 * t0.elapsed().as_secs_f64() / te.len() as f64;

            for (m, a, ms) in [
                ("naive (10)", acc10, ms10),
                ("BCM", accb, msb),
                ("early (11)", acc11, ms11),
            ] {
                t.row(&[
                    spec.name.to_string(),
                    k_label.to_string(),
                    m.to_string(),
                    format!("{:.1}", 100.0 * a),
                    format!("{ms:.3}"),
                ]);
            }
        }
    }
    t.print();
    println!(
        "\nexpected shape (paper Table 1): early (11) highest accuracy at the \
         lowest ms/sample; naive (10) and BCM degrade as k grows, BCM slowest."
    );
}
