//! Figure 4: degree-3 polynomial kernel on covtype-like and webspam-like —
//! objective vs time (a, c) and test accuracy vs time (b, d) for
//! DC-SVM / LIBSVM / LaSVM.

use dcsvm::baselines::lasvm;
use dcsvm::bench::{banner, fmt_secs};
use dcsvm::cache::KernelContext;
use dcsvm::data::synthetic::{covtype_like, generate_split, webspam_like};
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::metrics::relative_error;
use dcsvm::predict::SvmModel;
use dcsvm::solver::{solve_svm, SmoConfig, SmoSolver};

fn main() {
    banner("Figure 4", "polynomial kernel (degree 3): objective + accuracy vs time");
    let n = if std::env::var("FULL").is_ok() { 5000 } else { 2000 };
    // paper: covtype C=2 γ=1, webspam C=8 γ=16, η=0
    for (spec, c, gamma) in [(covtype_like(), 2.0, 1.0f32), (webspam_like(), 8.0, 16.0)] {
        let (tr, te) = generate_split(&spec, n, 700, 44);
        let kind = KernelKind::Poly { gamma, eta: 0.0 };
        let kern = NativeKernel::new(kind);
        println!("\n--- {} (poly³, C={c}, γ={gamma}) ---", spec.name);

        // reference optimum
        let star = solve_svm(&tr, &kern, SmoConfig { c, eps: 1e-7, ..Default::default() });

        // LIBSVM trace
        let tr_ctx = KernelContext::new(&tr, &kern, 256 << 20);
        let mut lib_series = Vec::new();
        let lib = SmoSolver::new(
            tr_ctx.view_full(),
            SmoConfig { c, eps: 1e-6, report_every: 400, ..Default::default() },
        )
        .solve_warm(None, &mut |p| lib_series.push((p.elapsed_s, p.objective)));

        // DC-SVM
        let cfg = DcSvmConfig {
            kind,
            c,
            levels: 3,
            sample_m: 128,
            eps_final: 1e-6,
            ..Default::default()
        };
        let dc = train(&tr, &kern, &cfg);

        // LaSVM
        let las = lasvm::train(
            &tr_ctx,
            &lasvm::LaSvmConfig { kind, c, eps: 1e-3, ..Default::default() },
        );

        println!("objective rel-err vs time:");
        for (name, series) in [("LIBSVM", &lib_series), ("DC-SVM", &dc.trace.points)] {
            for &(ts, f) in series.iter().step_by((series.len() / 4).max(1)) {
                println!(
                    "  {name:>8} t={:>8} rel-err={:.2e}",
                    fmt_secs(ts),
                    relative_error(f, star.objective)
                );
            }
        }

        println!("final accuracy vs time:");
        let acc = |alpha: &[f64]| {
            SvmModel::from_alpha(&tr, alpha, kind).accuracy(&te, &kern)
        };
        println!("  DC-SVM   t={:>8} acc={:.2}%", fmt_secs(dc.total_s), 100.0 * acc(&dc.alpha));
        println!("  LIBSVM   t={:>8} acc={:.2}%", fmt_secs(lib.elapsed_s), 100.0 * acc(&lib.alpha));
        println!("  LaSVM    t={:>8} acc={:.2}%", fmt_secs(las.elapsed_s), 100.0 * acc(&las.alpha));

        let rel = relative_error(dc.objective.unwrap(), star.objective);
        assert!(rel < 1e-3, "DC-SVM poly rel err {rel}");
    }
    println!(
        "\nexpected shape (paper Fig. 4): DC-SVM reduces the objective far \
         faster than LIBSVM under the polynomial kernel (the paper reports \
         >100x there; LIBSVM struggles to identify poly-kernel SVs)."
    );
}
