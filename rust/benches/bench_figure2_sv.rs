//! Figure 2: support-vector identification.
//!
//! (a/b/e/f) precision & recall of the SV set at every DC-SVM level vs the
//! final SV set, against CascadeSVM's per-level SV sets.
//! (c/d/g/h) SVs recovered over time: DC-SVM levels vs the cold solver's
//! shrinking trajectory.

use dcsvm::baselines::cascade;
use dcsvm::bench::{banner, fmt_secs, Table};
use dcsvm::cache::KernelContext;
use dcsvm::data::synthetic::{covtype_like, generate_split, ijcnn1_like};
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::metrics::sv_precision_recall;
use dcsvm::solver::{solve_svm, SmoConfig, SmoSolver};

fn main() {
    banner("Figure 2", "SV identification: DC-SVM levels vs CascadeSVM vs LIBSVM shrinking");
    // ijcnn1-like stands in for the paper's webspam panel (see bench_table1).
    for (spec, gamma) in [(covtype_like(), 32.0f32), (ijcnn1_like(), 4.0)] {
        let (tr, _) = generate_split(&spec, 2000, 200, 11);
        let kind = KernelKind::Rbf { gamma };
        let kern = NativeKernel::new(kind);
        let c = 4.0;
        println!("\n--- dataset {} (n={}) ---", spec.name, tr.len());

        // Reference SV set: high-precision solve.
        let star = solve_svm(&tr, &kern, SmoConfig { c, eps: 1e-7, ..Default::default() });
        println!("reference SVs: {}", star.sv_count);

        // DC-SVM per-level precision/recall.
        let cfg = DcSvmConfig {
            kind,
            c,
            levels: 4, // bottom level = 256 clusters, as in the paper
            k_base: 4,
            sample_m: 128,
            eps_final: 1e-6,
            keep_level_alphas: true,
            ..Default::default()
        };
        let dc = train(&tr, &kern, &cfg);
        let mut t = Table::new(&["method", "level (k)", "precision", "recall", "cum time"]);
        for ls in &dc.levels {
            let (p, r) = sv_precision_recall(ls.alpha.as_ref().unwrap(), &star.alpha);
            t.row(&[
                "DC-SVM".into(),
                format!("{} ({})", ls.level, ls.k),
                format!("{:.3}", p),
                format!("{:.3}", r),
                fmt_secs(ls.cumulative_s),
            ]);
        }

        // CascadeSVM: per-pass SV sets (recall only grows by luck — false
        // negatives cannot be recovered).
        let cres = cascade::train(
            &tr,
            &kern,
            &cascade::CascadeConfig { kind, c, depth: 4, ..Default::default() },
        );
        let (p, r) = sv_precision_recall(&cres.alpha, &star.alpha);
        t.row(&[
            "CascadeSVM".into(),
            format!("root ({} passes)", cres.level_sv_counts.len()),
            format!("{:.3}", p),
            format!("{:.3}", r),
            fmt_secs(cres.elapsed_s),
        ]);

        // LIBSVM shrinking trajectory: SV recall of the running α over time.
        let mut series = Vec::new();
        let ctx = KernelContext::new(&tr, &kern, 256 << 20);
        let mut solver = SmoSolver::new(
            ctx.view_full(),
            SmoConfig { c, eps: 1e-6, report_every: 500, ..Default::default() },
        );
        solver.solve_warm(None, &mut |p| {
            let (_, rec) = sv_precision_recall(p.alpha, &star.alpha);
            series.push((p.elapsed_s, rec));
        });
        for &(ts, rec) in series
            .iter()
            .step_by((series.len() / 5).max(1))
            .chain(series.last().into_iter())
        {
            t.row(&[
                "LIBSVM-shrink".into(),
                "(running)".into(),
                "—".into(),
                format!("{rec:.3}"),
                fmt_secs(ts),
            ]);
        }
        t.print();
    }
    println!(
        "\nexpected shape: DC-SVM ≥90% precision/recall even at the bottom \
         level and earlier in wall-clock than the shrinking trajectory; \
         CascadeSVM recall below DC-SVM."
    );
}
