//! Table 3: RBF kernel — time and test accuracy for all nine solvers on the
//! ijcnn1 / cifar / census / covtype counterparts.
//!
//! `FULL=1 cargo bench --bench bench_table3_rbf` runs the full (slower)
//! sizes; default sizes keep the whole suite 1-core friendly.

use dcsvm::bench::{banner, fmt_secs, Table};
use dcsvm::config::{Algo, RunConfig};
use dcsvm::harness;

fn main() {
    banner("Table 3", "RBF kernel: time(s) / acc(%) for all solvers");
    let full = std::env::var("FULL").is_ok();
    // (dataset, n_train, n_test, gamma, C) — γ/C in the spirit of the
    // paper's cross-validated settings, rescaled to the synthetic geometry.
    let settings: &[(&str, usize, usize, f64, f64)] = &[
        ("ijcnn1-like", if full { 6000 } else { 4000 }, 1000, 2.0, 32.0),
        ("cifar-like", if full { 3000 } else { 1500 }, 600, 2e-4, 8.0),
        ("census-like", if full { 5000 } else { 2500 }, 700, 4.0, 8.0),
        ("covtype-like", if full { 8000 } else { 5000 }, 1000, 32.0, 4.0),
    ];

    for &(dataset, ntr, nte, gamma, c) in settings {
        println!("\n--- {dataset}: n={ntr}, γ={gamma}, C={c} ---");
        let mut base = RunConfig::default();
        base.dataset = dataset.into();
        base.n_train = Some(ntr);
        base.n_test = Some(nte);
        base.gamma = gamma;
        base.c = c;
        base.levels = 2;
        base.sample_m = 128;
        base.budget = 48;
        // Constrained kernel cache — the paper's memory regime (its LIBSVM
        // runs cache ~1% of rows); this is where warm starts pay off.
        base.cache_mb = 8;
        base.eps = 1e-4;
        let (tr, te) = harness::load_dataset(&base).expect("dataset");

        let mut t = Table::new(&["solver", "time", "acc%"]);
        for algo in Algo::all() {
            let mut cfg = base.clone();
            cfg.algo = algo;
            match harness::run(&cfg, &tr, &te) {
                Ok(out) => t.row(&[
                    out.algo.to_string(),
                    fmt_secs(out.train_s),
                    format!("{:.2}", 100.0 * out.accuracy),
                ]),
                Err(e) => t.row(&[algo.name().to_string(), "ERR".into(), format!("{e}")]),
            }
        }
        t.print();
    }
    println!(
        "\nexpected shape (paper Table 3): DC-SVM (early) fastest; DC-SVM \
         matches LIBSVM accuracy in less time; approximate solvers \
         (LLSVM/FastFood/SpSVM/LTPU) below exact accuracy."
    );
}
