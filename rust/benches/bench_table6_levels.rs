//! Table 6: DC-SVM run time per level on covtype-like — clustering time is
//! roughly constant per level while training time grows toward the top.

use dcsvm::bench::{banner, fmt_secs, Table};
use dcsvm::data::synthetic::{covtype_like, generate_split};
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};

fn main() {
    banner("Table 6", "per-level clustering vs training time (covtype-like)");
    let n = if std::env::var("FULL").is_ok() { 8000 } else { 4000 };
    let (tr, _) = generate_split(&covtype_like(), n, 500, 55);
    let kind = KernelKind::Rbf { gamma: 32.0 };
    let kern = NativeKernel::new(kind);

    let cfg = DcSvmConfig {
        kind,
        c: 1.0,
        levels: 4, // levels 4..1 = k 256..4, then level 0 = final solve
        k_base: 4,
        sample_m: 128,
        eps_final: 1e-5,
        cache_bytes: 16 << 20,
        ..Default::default()
    };
    let dc = train(&tr, &kern, &cfg);

    let mut t = Table::new(&["level", "k", "clustering", "training", "SVs", "sub-iters"]);
    for ls in &dc.levels {
        t.row(&[
            ls.level.to_string(),
            ls.k.to_string(),
            fmt_secs(ls.clustering_s),
            fmt_secs(ls.training_s),
            ls.sv_count.to_string(),
            ls.sub_iterations.to_string(),
        ]);
    }
    t.row(&[
        "0 (final)".into(),
        "1".into(),
        "—".into(),
        fmt_secs(dc.refine_s + dc.final_s),
        dc.sv_count().to_string(),
        dc.final_iterations.to_string(),
    ]);
    t.print();

    let clustering: Vec<f64> = dc.levels.iter().map(|l| l.clustering_s).collect();
    let spread = clustering.iter().cloned().fold(f64::MIN, f64::max)
        / clustering.iter().cloned().fold(f64::MAX, f64::min).max(1e-9);
    println!(
        "\nexpected shape (paper Table 6): clustering ~constant per level \
         (max/min spread here: {spread:.1}x), training time grows toward the \
         top; clustering is a small fraction of total."
    );
}
