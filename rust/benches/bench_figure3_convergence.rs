//! Figure 3: convergence and accuracy over time.
//!
//! (a–c) relative objective error (f − f*)/|f*| vs wall-clock for the exact
//! solvers (DC-SVM / LIBSVM / CascadeSVM final stage);
//! (d–f) test accuracy vs wall-clock for all solver families (each
//! approximate solver contributes points at several budget settings).
//! CSV series are written to target/figure3_*.csv for plotting.

use dcsvm::baselines::cascade;
use dcsvm::bench::{banner, fmt_secs};
use dcsvm::cache::KernelContext;
use dcsvm::config::{Algo, RunConfig};
use dcsvm::data::synthetic::{covtype_like, generate_split};
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::harness;
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::metrics::relative_error;
use dcsvm::solver::{solve_svm, SmoConfig, SmoSolver};

fn main() {
    banner("Figure 3", "objective rel-err vs time (a–c) and test accuracy vs time (d–f)");
    let n = if std::env::var("FULL").is_ok() { 8000 } else { 5000 };
    let (tr, te) = generate_split(&covtype_like(), n, 800, 33);
    let kind = KernelKind::Rbf { gamma: 32.0 };
    let kern = NativeKernel::new(kind);
    let c = 4.0;
    let cache = 16usize << 20; // constrained cache: the paper's regime

    // Reference optimum.
    let star = solve_svm(&tr, &kern, SmoConfig { c, eps: 1e-8, ..Default::default() });
    let f_star = star.objective;
    println!("n={n}, f* = {f_star:.4}");

    // ---- (a–c): objective vs time ---------------------------------------
    println!("\n[objective rel-err vs time]");
    let mut libsvm_series = Vec::new();
    // Constrained-budget context: the paper's memory regime.
    let lib_ctx = KernelContext::new(&tr, &kern, cache);
    SmoSolver::new(
        lib_ctx.view_full(),
        SmoConfig { c, eps: 1e-6, report_every: 200, ..Default::default() },
    )
    .solve_warm(None, &mut |p| libsvm_series.push((p.elapsed_s, p.objective)));

    let cfg = DcSvmConfig {
        kind,
        c,
        levels: 3,
        sample_m: 128,
        eps_final: 1e-6,
        cache_bytes: cache,
        ..Default::default()
    };
    let dc = train(&tr, &kern, &cfg);

    let mut csv = String::from("solver,t_s,rel_err\n");
    println!("  {:>12} {:>10} {:>10}", "solver", "t", "rel-err");
    for (name, series) in [
        ("LIBSVM", &libsvm_series),
        ("DC-SVM", &dc.trace.points),
    ] {
        for &(ts, f) in series.iter().step_by((series.len() / 6).max(1)) {
            let re = relative_error(f, f_star);
            println!("  {name:>12} {:>10} {re:>10.2e}", fmt_secs(ts));
            csv.push_str(&format!("{name},{ts:.4},{re:.6e}\n"));
        }
    }
    std::fs::write("target/figure3_objective.csv", &csv).ok();

    // ---- (d–f): accuracy vs time -----------------------------------------
    println!("\n[test accuracy vs time — one line per solver, points = budgets]");
    let mut csv = String::from("solver,t_s,acc\n");
    let mut emit = |name: &str, t: f64, acc: f64| {
        println!("  {name:>14} t={:>8} acc={:.2}%", fmt_secs(t), 100.0 * acc);
        csv.push_str(&format!("{name},{t:.4},{acc:.4}\n"));
    };

    // exact family: DC-SVM early points per level + final
    let em = dc.early_model.as_ref().unwrap();
    emit("DC-SVM(early)", dc.levels.last().unwrap().cumulative_s, em.accuracy(&te, &kern));
    {
        let model = dcsvm::predict::SvmModel::from_alpha(&tr, &dc.alpha, kind);
        emit("DC-SVM", dc.total_s, model.accuracy(&te, &kern));
    }
    {
        let model = dcsvm::predict::SvmModel::from_alpha(&tr, &star.alpha, kind);
        emit("LIBSVM", star.elapsed_s, model.accuracy(&te, &kern));
    }
    // CascadeSVM
    let cres = cascade::train(
        &tr,
        &kern,
        &cascade::CascadeConfig { kind, c, depth: 3, ..Default::default() },
    );
    emit("CascadeSVM", cres.elapsed_s, cres.model.accuracy(&te, &kern));

    // approximate solvers at increasing budgets
    let mut base = RunConfig::default();
    base.dataset = "covtype-like".into();
    base.n_train = Some(n);
    base.n_test = Some(800);
    base.gamma = 32.0;
    base.c = c;
    base.backend = "native".into();
    for algo in [Algo::Llsvm, Algo::Fastfood, Algo::Ltpu, Algo::Spsvm, Algo::LaSvm] {
        for budget in [8usize, 24, 64] {
            let mut cfgb = base.clone();
            cfgb.algo = algo;
            cfgb.budget = budget;
            if algo == Algo::LaSvm && budget != 24 {
                continue; // online solver has no budget knob; one point
            }
            if let Ok(out) = harness::run(&cfgb, &tr, &te) {
                emit(out.algo, out.train_s, out.accuracy);
            }
        }
    }
    std::fs::write("target/figure3_accuracy.csv", &csv).ok();
    println!(
        "\nexpected shape: DC-SVM reaches low rel-err before LIBSVM; \
         DC-SVM(early) dominates the accuracy/time frontier; approximate \
         solvers plateau below exact accuracy. CSVs in target/figure3_*.csv"
    );
}
