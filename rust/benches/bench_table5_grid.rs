//! Tables 7–10 + Table 5 + Figures 5–8: the (C, γ) robustness grid.
//!
//! For each dataset, a 3×3 grid over C, γ ∈ {2⁻⁶, 2¹, 2⁶} comparing
//! DC-SVM (early) / DC-SVM / LIBSVM time and accuracy; the Table-5 footer
//! accumulates total grid time, and a Figures-5–8-style accuracy matrix is
//! printed per solver.

use dcsvm::bench::{banner, fmt_secs, Table};
use dcsvm::config::{Algo, RunConfig};
use dcsvm::harness;

fn main() {
    banner(
        "Tables 7-10 / Table 5 / Figures 5-8",
        "(C, γ) grid: DC-SVM(early) / DC-SVM / LIBSVM",
    );
    let full = std::env::var("FULL").is_ok();
    let datasets: &[&str] = if full {
        &["ijcnn1-like", "covtype-like", "webspam-like", "census-like"]
    } else {
        &["ijcnn1-like", "covtype-like"]
    };
    let n = if full { 4000 } else { 2500 };
    let exps = [-6i32, 1, 6];

    let mut grand_totals: std::collections::BTreeMap<&str, f64> = Default::default();

    for &dataset in datasets {
        println!("\n--- {dataset} (n={n}) ---");
        let mut base = RunConfig::default();
        base.dataset = dataset.into();
        base.n_train = Some(n);
        base.n_test = Some(n / 3);
        base.levels = 2;
        base.sample_m = 96;
        base.backend = "native".into();
        base.cache_mb = 4;
        let (tr, te) = harness::load_dataset(&base).expect("dataset");

        let mut t = Table::new(&["C", "γ", "early t", "early acc", "dc t", "dc acc", "lib t", "lib acc"]);
        let mut faster = 0usize;
        let mut total = 0usize;
        // accuracy matrices for the Figures 5–8 heat map view
        let mut acc_matrix: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();

        for &ce in &exps {
            for &ge in &exps {
                let mut row = vec![format!("2^{ce}"), format!("2^{ge}")];
                let mut times = [0f64; 3];
                for (i, algo) in [Algo::DcSvmEarly, Algo::DcSvm, Algo::Libsvm]
                    .iter()
                    .enumerate()
                {
                    let mut cfg = base.clone();
                    cfg.algo = *algo;
                    cfg.c = 2f64.powi(ce);
                    cfg.gamma = 2f64.powi(ge);
                    let out = harness::run(&cfg, &tr, &te).expect("run");
                    *grand_totals.entry(out.algo).or_default() += out.train_s;
                    times[i] = out.train_s;
                    row.push(fmt_secs(out.train_s));
                    row.push(format!("{:.1}", 100.0 * out.accuracy));
                    acc_matrix.entry(out.algo).or_default().push(out.accuracy);
                }
                total += 1;
                if times[1] <= times[2] {
                    faster += 1;
                }
                t.row(&row);
            }
        }
        t.print();
        println!("DC-SVM faster than LIBSVM on {faster}/{total} settings (paper: 96/100)");

        println!("accuracy matrices (rows C=2^-6,2^1,2^6; cols γ=2^-6,2^1,2^6) — Figures 5-8 view:");
        for (algo, accs) in &acc_matrix {
            println!("  {algo}:");
            for r in 0..3 {
                let cells: Vec<String> =
                    (0..3).map(|c| format!("{:5.1}", 100.0 * accs[r * 3 + c])).collect();
                println!("    {}", cells.join(" "));
            }
        }
    }

    println!("\naccumulated grid time (Table 5):");
    for (algo, total) in grand_totals {
        println!("  {algo}: {}", fmt_secs(total));
    }
    println!(
        "\nexpected shape: DC-SVM (early) total ≪ DC-SVM total < LIBSVM \
         total; early accuracy tracks exact across the whole grid \
         (robustness, Figures 5-8)."
    );
}
