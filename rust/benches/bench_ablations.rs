//! Ablations of DC-SVM's design choices (DESIGN.md §Perf / §6):
//!
//!   A1 kernel-kmeans partition   vs random partition (divide quality)
//!   A2 adaptive SV sampling      vs always sampling from all data
//!   A3 refine step on            vs off
//!   A4 multilevel (levels=3)     vs single-level (levels=1)
//!   A5 warm-start shrink + row-batch prefetch vs neither (solver opts)
//!
//! Each row: total train time, final-stage iterations, objective rel-err
//! vs the reference optimum, early-model accuracy where applicable.

use dcsvm::bench::{banner, fmt_secs, Table};
use dcsvm::cache::KernelContext;
use dcsvm::data::synthetic::{covtype_like, generate_split};
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::metrics::relative_error;
use dcsvm::solver::{solve_svm, SmoConfig, SmoSolver};

fn main() {
    banner("Ablations", "DC-SVM design choices, one knob at a time");
    let n = if std::env::var("FULL").is_ok() { 6000 } else { 3000 };
    let (tr, te) = generate_split(&covtype_like(), n, 800, 77);
    let kind = KernelKind::Rbf { gamma: 32.0 };
    let kern = NativeKernel::new(kind);
    let c = 1.0;
    let cache = 16usize << 20;

    let star = solve_svm(&tr, &kern, SmoConfig { c, eps: 1e-8, ..Default::default() });
    println!("n={n}, f* = {:.4}, SVs = {}", star.objective, star.sv_count);

    let base = DcSvmConfig {
        kind,
        c,
        levels: 3,
        k_base: 4,
        sample_m: 128,
        eps_final: 1e-5,
        cache_bytes: cache,
        ..Default::default()
    };

    let mut t = Table::new(&["config", "time", "final iters", "rel-err", "early acc%"]);
    let mut run = |name: &str, cfg: &DcSvmConfig| {
        let dc = train(&tr, &kern, cfg);
        let early_acc = dc
            .early_model
            .as_ref()
            .map(|em| format!("{:.2}", 100.0 * em.accuracy(&te, &kern)))
            .unwrap_or_else(|| "—".into());
        t.row(&[
            name.to_string(),
            fmt_secs(dc.total_s),
            dc.final_iterations.to_string(),
            format!("{:.1e}", relative_error(dc.objective.unwrap(), star.objective)),
            early_acc,
        ]);
    };

    run("baseline (all on)", &base);
    run("A2 no adaptive sampling", &DcSvmConfig { adaptive: false, ..base.clone() });
    run("A3 no refine step", &DcSvmConfig { refine: false, ..base.clone() });
    run("A4 single level (k=4)", &DcSvmConfig { levels: 1, ..base.clone() });
    run("A4 single level (k=64)", &DcSvmConfig { levels: 1, k_base: 64, ..base.clone() });

    // A1: random partition = adaptive off + sample_m tiny (degenerate
    // clustering) — the closest in-driver knob to a random split; the true
    // random-partition gap is quantified in bench_figure1_bound.
    run("A1 degenerate clustering (m=8)", &DcSvmConfig { sample_m: 8, ..base.clone() });

    // A5: solver-level optimizations, measured on the cold whole-problem
    // solve (warm-start shrink only acts on warm starts; row batching acts
    // everywhere).
    for (name, batch) in [("A5 row_batch=1 (no prefetch)", 1usize), ("A5 row_batch=64", 64)] {
        // Fresh constrained-budget context per setting: A5 measures the
        // solver's own prefetch policy, not cross-run cache reuse.
        let ctx = KernelContext::new(&tr, &kern, cache);
        let res = SmoSolver::new(
            ctx.view_full(),
            SmoConfig { c, eps: 1e-5, row_batch: batch, ..Default::default() },
        )
        .solve();
        t.row(&[
            name.to_string(),
            fmt_secs(res.elapsed_s),
            res.iterations.to_string(),
            format!("{:.1e}", relative_error(res.objective, star.objective)),
            "—".into(),
        ]);
    }
    t.print();
    println!(
        "\nreading: every knob matters — adaptive sampling and refine cut \
         final-stage iterations; multilevel beats both single-level extremes \
         (paper §4 trade-off); degenerate clustering approaches the random-\
         partition regime of Figure 1."
    );
}
