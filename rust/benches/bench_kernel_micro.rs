//! L1 hot-path microbench: kernel-block evaluation, native vs PJRT (AOT
//! Pallas), across tile shapes — the §Perf evidence for backend and tile
//! choices. Reports effective GFLOP/s (2·nq·nd·d flops for the cross term).

use dcsvm::bench::{banner, fmt_secs, time_fn, Table};
use dcsvm::harness;
use dcsvm::kernel::native::{dot_detected, dot_scalar, NativeKernel};
use dcsvm::kernel::{simd_tier, BlockKernel, KernelKind};
use dcsvm::util::prng::Pcg64;
use dcsvm::util::threadpool::default_threads;

fn rand_rows(rng: &mut Pcg64, n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
    let norms = x.chunks(d).map(|r| r.iter().map(|&v| v * v).sum()).collect();
    (x, norms)
}

fn main() {
    banner("kernel µbench", "block kernel: native vs PJRT across shapes (L1 hot path)");
    let engine = harness::global_engine();
    if engine.is_none() {
        println!("NOTE: artifacts/ not built — PJRT column skipped");
    }
    let kind = KernelKind::Rbf { gamma: 1.0 };
    let native = NativeKernel::new(kind);
    let mut rng = Pcg64::new(1);

    let shapes = [
        (1usize, 2000usize, 54usize), // solver row fetch, unbatched
        (64, 2000, 54),               // solver row fetch, batched (row_batch)
        (64, 1024, 128),              // exact slim tile
        (256, 1024, 128),             // exact wide tile
        (256, 4096, 128),             // bulk (kmeans assignment / prediction)
        (512, 8192, 54),              // large bulk
    ];

    let mut t = Table::new(&["nq x nd x d", "native", "nat GF/s", "pjrt", "pjrt GF/s", "pjrt/nat"]);
    for &(nq, nd, d) in &shapes {
        let (xq, qn) = rand_rows(&mut rng, nq, d);
        let (xd, dn) = rand_rows(&mut rng, nd, d);
        let mut out = vec![0f32; nq * nd];
        let flops = 2.0 * nq as f64 * nd as f64 * d as f64;

        let nat = time_fn(1, 5, || {
            native.block(&xq, &qn, &xd, &dn, d, &mut out);
        });
        let (pjrt_s, ratio, gfp) = if let Some(e) = engine {
            let pk = dcsvm::runtime::PjrtKernel::new(e, kind);
            let pj = time_fn(1, 5, || {
                pk.block(&xq, &qn, &xd, &dn, d, &mut out);
            });
            (
                fmt_secs(pj.median_s),
                format!("{:.2}x", pj.median_s / nat.median_s),
                format!("{:.1}", flops / pj.median_s / 1e9),
            )
        } else {
            ("—".into(), "—".into(), "—".into())
        };
        t.row(&[
            format!("{nq}x{nd}x{d}"),
            fmt_secs(nat.median_s),
            format!("{:.1}", flops / nat.median_s / 1e9),
            pjrt_s,
            gfp,
            ratio,
        ]);
    }
    t.print();
    println!(
        "\nreading: single-row fetches are dispatch-bound on PJRT (why the \
         solver batches rows); at tile-aligned bulk shapes the XLA path \
         amortizes and the same HLO maps to MXU tiles on a real TPU \
         (DESIGN.md §Hardware-Adaptation)."
    );

    // ---- ISSUE satellite: 1-thread vs N-thread row-panel dispatch -------
    // Large blocks fan out over output-row panels (`block_par`); results
    // are bit-identical (verified per shape below), only wall time moves.
    // Expected: ≥1.5× at 4 threads on the large shapes (machine-dependent;
    // tiny shapes stay below the parallel threshold and report 1.00x).
    let threads = default_threads().clamp(4, 8);
    banner(
        "thread scaling",
        "native block dispatch, 1 thread vs row-panel parallel (bit-identical)",
    );
    let th_header = format!("{threads} threads");
    let mut ts = Table::new(&["nq x nd x d", "1 thread", &th_header, "speedup"]);
    for &(nq, nd, d) in &[
        (64usize, 2000usize, 54usize), // batched warm prefetch
        (256, 4096, 128),              // bulk kmeans/predict shape
        (512, 8192, 54),               // large bulk
        (1024, 8192, 128),             // saturating block
    ] {
        let (xq, qn) = rand_rows(&mut rng, nq, d);
        let (xd, dn) = rand_rows(&mut rng, nd, d);
        let mut serial = vec![0f32; nq * nd];
        let mut par = vec![0f32; nq * nd];
        let one = time_fn(1, 3, || {
            native.block_par(&xq, &qn, &xd, &dn, d, 1, &mut serial);
        });
        let many = time_fn(1, 3, || {
            native.block_par(&xq, &qn, &xd, &dn, d, threads, &mut par);
        });
        assert!(
            serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
            "block_par not bit-identical at {nq}x{nd}x{d}"
        );
        ts.row(&[
            format!("{nq}x{nd}x{d}"),
            fmt_secs(one.median_s),
            fmt_secs(many.median_s),
            format!("{:.2}x", one.median_s / many.median_s),
        ]);
    }
    ts.print();

    // ---- ISSUE tentpole: inner-dot SIMD tier vs forced scalar -----------
    // Single-thread throughput of the innermost `dot1` both ways. The two
    // paths are bit-identical by construction (lane structure + reduction
    // order match); asserted on every sweep before timing. Acceptance on an
    // AVX2 host: ≥4× on the long-vector rows. `DCSVM_FORCE_SCALAR=1` pins
    // the tier, making the ratio column report 1.00x.
    let tier = simd_tier().name();
    banner(
        "inner dot tiers",
        &format!("dot1 scalar vs detected tier ({tier}), single thread, bit-identical"),
    );
    let mut td = Table::new(&["dim", "scalar GF/s", &format!("{tier} GF/s"), "speedup"]);
    for &d in &[54usize, 128, 300, 784, 2048] {
        // One query row against a resident panel of rows: the solver's
        // row-fetch shape, small enough to stay cache-hot so the timer sees
        // arithmetic, not memory.
        let nd = (1 << 20) / d.max(1); // ~4 MB of f32 panel rows total
        let (q, _) = rand_rows(&mut rng, 1, d);
        let (xd, _) = rand_rows(&mut rng, nd, d);
        for row in xd.chunks_exact(d) {
            let a = dot_scalar(&q, row);
            let b = dot_detected(&q, row);
            assert!(
                a.to_bits() == b.to_bits(),
                "dot tiers disagree at dim {d}: {a} vs {b}"
            );
        }
        let flops = 2.0 * nd as f64 * d as f64;
        let mut sink = 0f32;
        let sc = time_fn(1, 5, || {
            sink = xd.chunks_exact(d).map(|row| dot_scalar(&q, row)).sum();
        });
        let sc_sink = sink;
        let dt = time_fn(1, 5, || {
            sink = xd.chunks_exact(d).map(|row| dot_detected(&q, row)).sum();
        });
        assert!(sc_sink.to_bits() == sink.to_bits(), "tier sweep sums diverge");
        td.row(&[
            format!("{d}"),
            format!("{:.2}", flops / sc.median_s / 1e9),
            format!("{:.2}", flops / dt.median_s / 1e9),
            format!("{:.2}x", sc.median_s / dt.median_s),
        ]);
    }
    td.print();
    println!(
        "\nreading: tier = {tier} (runtime-detected once per process; \
         DCSVM_FORCE_SCALAR=1 forces scalar). Both columns run the same \
         8-lane accumulator layout and pairwise reduction, so values are \
         bit-identical — only throughput moves. EXPERIMENTS.md records the \
         per-host table."
    );
}
