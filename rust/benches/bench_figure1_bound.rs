//! Figure 1: tightness of the Theorem-1 bound.
//!
//! On a covtype-like subset, for k = 2..32 clusters, compare
//!   (a) the bound ½C²D(π) under the kernel-kmeans partition,
//!   (b) the actual gap f(ᾱ) − f(α*) under that partition,
//!   (c) the actual gap under a *random* partition.
//! Paper's claim: (a) ≈ (b) (curves nearly overlap), and both are far below
//! (c) — kernel kmeans is what makes ᾱ a good warm start.

use dcsvm::bench::{banner, Table};
use dcsvm::cache::KernelContext;
use dcsvm::data::synthetic::{covtype_like, generate};
use dcsvm::kernel::{native::NativeKernel, KernelKind};
use dcsvm::kmeans::{off_diagonal_mass, two_step_partition, Partition};
use dcsvm::metrics::objective_of;
use dcsvm::solver::{solve_svm, SmoConfig, SmoSolver};
use dcsvm::util::prng::Pcg64;

fn solve_partition(ctx: &KernelContext, part: &Partition, c: f64) -> Vec<f64> {
    let mut alpha = vec![0f64; ctx.len()];
    for members in &part.members {
        if members.is_empty() {
            continue;
        }
        let res = SmoSolver::new(
            ctx.view(members),
            SmoConfig { c, eps: 1e-7, ..Default::default() },
        )
        .solve();
        for (t, &i) in members.iter().enumerate() {
            alpha[i] = res.alpha[t];
        }
    }
    alpha
}

fn main() {
    banner("Figure 1", "Theorem-1 bound vs actual objective gap, kernel-kmeans vs random partition");
    let n = 1500;
    let c = 1.0;
    let mut rng = Pcg64::new(7);
    let ds = generate(&covtype_like(), n, &mut rng);
    let kern = NativeKernel::new(KernelKind::Rbf { gamma: 32.0 });
    let ctx = KernelContext::new(&ds, &kern, 256 << 20);

    let star = solve_svm(&ds, &kern, SmoConfig { c, eps: 1e-8, ..Default::default() });
    println!("n={n}, f(α*) = {:.4}", star.objective);

    let mut t = Table::new(&[
        "k",
        "bound ½C²D(π)",
        "gap kernel-kmeans",
        "gap random",
        "bound/gap",
    ]);
    for k in [2usize, 4, 8, 16, 32] {
        let (_, part) = two_step_partition(&ctx, k, 128, None, &mut rng);
        let alpha_k = solve_partition(&ctx, &part, c);
        let gap_k = objective_of(&ds, &kern, &alpha_k) - star.objective;
        let bound = 0.5 * c * c * off_diagonal_mass(&ctx, &part.assign);

        let rpart = Partition::random(n, k, &mut rng);
        let alpha_r = solve_partition(&ctx, &rpart, c);
        let gap_r = objective_of(&ds, &kern, &alpha_r) - star.objective;

        t.row(&[
            k.to_string(),
            format!("{bound:.3}"),
            format!("{gap_k:.3}"),
            format!("{gap_r:.3}"),
            format!("{:.1}", bound / gap_k.max(1e-9)),
        ]);
        assert!(gap_k >= -1e-6 && gap_k <= bound + 1e-6, "Theorem 1 violated");
    }
    t.print();
    println!("\nexpected shape: bound tracks the kernel-kmeans gap (small ratio), random gap ≫ both.");
}
