# Repo task runner. `make verify` is the tier-1 gate (mirrors ci.yml for
# environments without GitHub Actions).

.PHONY: verify fmt test build artifacts

verify: build test

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --check

# AOT-compile the Pallas/XLA kernel artifacts (requires the python/ stack;
# the Rust side runs on the native backend without them).
artifacts:
	python3 -m python.compile.aot
