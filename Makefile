# Repo task runner. `make verify` is the tier-1 gate plus the lint and doc
# gates (mirrors ci.yml for environments without GitHub Actions).

.PHONY: verify fmt test build clippy doc linkcheck bench-smoke bench-diff artifacts

verify: build test clippy doc linkcheck

build:
	cargo build --release

test:
	cargo test -q

# Lint gate: clippy across every target; any warning fails (mirrors the CI
# `clippy` job).
clippy:
	cargo clippy --all-targets -- -D warnings

# Rustdoc gate: broken intra-doc links (and any other rustdoc warning)
# fail the build. `--lib` because the bin target shares the crate name.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --lib

# Dead intra-repo links/anchors in the top-level docs fail the build.
linkcheck:
	python3 scripts/check_links.py README.md ARCHITECTURE.md EXPERIMENTS.md PROTOCOL.md

fmt:
	cargo fmt --check

# CI perf smoke: train + serve a small synthetic workload and emit
# BENCH_ci.json; fails if any structured counter is missing (mirrors the
# CI `bench-smoke` job).
bench-smoke: build
	python3 scripts/bench_smoke.py --binary target/release/dcsvm --out BENCH_ci.json

# Thread-invariance check: bench_smoke at 1 and 2 threads must emit
# bit-identical serve decisions (mirrors the CI `bench-smoke` job's
# verification step; `bench_diff.py diff` runs in CI against the previous
# run's cached artifact).
bench-diff: build
	python3 scripts/bench_smoke.py --binary target/release/dcsvm --out BENCH_ci.json --threads 2
	python3 scripts/bench_smoke.py --binary target/release/dcsvm --out BENCH_ci_t1.json --threads 1
	python3 scripts/bench_diff.py identical BENCH_ci_t1.json BENCH_ci.json \
	  --fields serve.decisions train.accuracy train.svs train.objective \
	  multiclass.serve.lines multiclass.train.accuracy

# AOT-compile the Pallas/XLA kernel artifacts (requires the python/ stack;
# the Rust side runs on the native backend without them).
artifacts:
	python3 -m python.compile.aot
