# Repo task runner. `make verify` is the tier-1 gate plus the doc gates
# (mirrors ci.yml for environments without GitHub Actions).

.PHONY: verify fmt test build doc linkcheck artifacts

verify: build test doc linkcheck

build:
	cargo build --release

test:
	cargo test -q

# Rustdoc gate: broken intra-doc links (and any other rustdoc warning)
# fail the build. `--lib` because the bin target shares the crate name.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --lib

# Dead intra-repo links/anchors in the top-level docs fail the build.
linkcheck:
	python3 scripts/check_links.py README.md ARCHITECTURE.md EXPERIMENTS.md PROTOCOL.md

fmt:
	cargo fmt --check

# AOT-compile the Pallas/XLA kernel artifacts (requires the python/ stack;
# the Rust side runs on the native backend without them).
artifacts:
	python3 -m python.compile.aot
