"""L2: JAX compute graphs over the Pallas kernels, with the AOT signatures.

This is the layer aot.py lowers to HLO text. Each public ``*_graph``
function is a pure jax function whose positional signature exactly matches
the artifact's runtime input order (documented in artifacts/manifest.json and
mirrored by rust/src/runtime/).

The graphs are intentionally thin — the paper's compute hot-spot *is* the
kernel block evaluation, so L2's job is (a) giving the kernels stable AOT
signatures, and (b) providing padded wrappers used by the python tests to
exercise arbitrary (non-tile-multiple) shapes the way the Rust runtime does.
"""

import jax.numpy as jnp

from .kernels.rbf import rbf_block, QT, DT
from .kernels.poly import poly_block, lin_block
from .kernels.decision import rbf_decision, poly_decision

# ---------------------------------------------------------------------------
# Artifact shape catalog. Every entry becomes artifacts/<name>.hlo.txt.
# D_PAD is the padded feature dimension all datasets are embedded into
# (zero-padding is exact for rbf given norms are inputs, and for poly/linear
# trivially). Two query-block variants per op: a slim one for kernel-row
# fetches from the solver hot loop, a wide one for bulk work (kmeans
# assignment, prediction, warm-start gradients).
# ---------------------------------------------------------------------------
D_PAD = 128
NQ_SLIM = 64
NQ_WIDE = 256
ND_BLK = 1024

assert NQ_SLIM % QT == 0 and NQ_WIDE % QT == 0 and ND_BLK % DT == 0


def rbf_block_graph(xq, xd, nq2, nd2, gamma):
    """AOT graph: RBF kernel block (see kernels/rbf.py)."""
    return rbf_block(xq, xd, nq2, nd2, gamma)


def poly_block_graph(xq, xd, gamma, eta):
    """AOT graph: degree-3 polynomial kernel block."""
    return poly_block(xq, xd, gamma, eta)


def lin_block_graph(xq, xd):
    """AOT graph: linear kernel block."""
    return lin_block(xq, xd)


def rbf_decision_graph(xq, xd, nq2, nd2, coef, gamma):
    """AOT graph: fused RBF decision values."""
    return rbf_decision(xq, xd, nq2, nd2, coef, gamma)


def poly_decision_graph(xq, xd, coef, gamma, eta):
    """AOT graph: fused polynomial decision values."""
    return poly_decision(xq, xd, coef, gamma, eta)


# ---------------------------------------------------------------------------
# Padded wrappers: compute on arbitrary shapes by embedding into tile
# multiples, exactly as the Rust runtime does. Used by python/tests to verify
# that padding is exact.
# ---------------------------------------------------------------------------

def _pad2(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def _pad1(a, n):
    return jnp.pad(a, (0, n - a.shape[0]))


def _ceil_to(x, m):
    return ((x + m - 1) // m) * m


def rbf_block_padded(xq, xd, gamma):
    """RBF block of arbitrary shape via padding to tile multiples."""
    nq, nd = xq.shape[0], xd.shape[0]
    pq, pd_ = _ceil_to(max(nq, 1), QT), _ceil_to(max(nd, 1), DT)
    dpad = _ceil_to(xq.shape[1], 8)
    xqp, xdp = _pad2(xq, pq, dpad), _pad2(xd, pd_, dpad)
    nq2 = _pad1((xq * xq).sum(axis=1), pq)
    nd2 = _pad1((xd * xd).sum(axis=1), pd_)
    out = rbf_block(xqp, xdp, nq2, nd2, jnp.array([gamma], jnp.float32))
    return out[:nq, :nd]


def poly_block_padded(xq, xd, gamma, eta):
    """Polynomial block of arbitrary shape via padding."""
    nq, nd = xq.shape[0], xd.shape[0]
    pq, pd_ = _ceil_to(max(nq, 1), QT), _ceil_to(max(nd, 1), DT)
    dpad = _ceil_to(xq.shape[1], 8)
    out = poly_block(_pad2(xq, pq, dpad), _pad2(xd, pd_, dpad),
                     jnp.array([gamma], jnp.float32),
                     jnp.array([eta], jnp.float32))
    return out[:nq, :nd]


def rbf_decision_padded(xq, xd, coef, gamma):
    """Fused RBF decision values of arbitrary shape via padding."""
    nq, nd = xq.shape[0], xd.shape[0]
    pq, pd_ = _ceil_to(max(nq, 1), QT), _ceil_to(max(nd, 1), DT)
    dpad = _ceil_to(xq.shape[1], 8)
    xqp, xdp = _pad2(xq, pq, dpad), _pad2(xd, pd_, dpad)
    nq2 = _pad1((xq * xq).sum(axis=1), pq)
    nd2 = _pad1((xd * xd).sum(axis=1), pd_)
    out = rbf_decision(xqp, xdp, nq2, nd2, _pad1(coef, pd_),
                       jnp.array([gamma], jnp.float32))
    return out[:nq]
