"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run once via ``make artifacts`` (python -m compile.aot --out-dir ../artifacts).
Python never runs after this; the Rust runtime loads the text with
``HloModuleProto::from_text_file``.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. (See /opt/xla-example/README.md.)

Besides the .hlo.txt files this writes artifacts/manifest.json describing
each artifact's input order/shapes/dtypes, which the Rust artifact registry
validates against at load time.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, F32)


def catalog():
    """The artifact catalog: name -> (graph fn, input specs).

    Input order here is the runtime ABI; rust/src/runtime/exec.rs constructs
    its Literal argument lists in exactly this order.
    """
    d, nqs, nqw, nd = M.D_PAD, M.NQ_SLIM, M.NQ_WIDE, M.ND_BLK
    cat = {}

    def add(name, fn, specs):
        cat[name] = (fn, specs)

    for tag, nq in (("slim", nqs), ("wide", nqw)):
        add(f"rbf_block_{tag}", M.rbf_block_graph,
            [_spec((nq, d)), _spec((nd, d)), _spec((nq,)), _spec((nd,)),
             _spec((1,))])
        add(f"poly_block_{tag}", M.poly_block_graph,
            [_spec((nq, d)), _spec((nd, d)), _spec((1,)), _spec((1,))])
    add("lin_block_wide", M.lin_block_graph,
        [_spec((M.NQ_WIDE, d)), _spec((nd, d))])
    add("rbf_decision_wide", M.rbf_decision_graph,
        [_spec((nqw, d)), _spec((nd, d)), _spec((nqw,)), _spec((nd,)),
         _spec((nd,)), _spec((1,))])
    add("poly_decision_wide", M.poly_decision_graph,
        [_spec((nqw, d)), _spec((nd, d)), _spec((nd,)), _spec((1,)),
         _spec((1,))])
    return cat


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, only=None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"d_pad": M.D_PAD, "nq_slim": M.NQ_SLIM, "nq_wide": M.NQ_WIDE,
                "nd_blk": M.ND_BLK, "artifacts": {}}
    for name, (fn, specs) in catalog().items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_aval = lowered.out_info
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of artifact names to rebuild")
    args = ap.parse_args()
    print(f"lowering artifacts to {args.out_dir}")
    build(args.out_dir, args.only)
    print("done")


if __name__ == "__main__":
    main()
