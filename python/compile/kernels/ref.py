"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package must match its oracle here to float32
tolerance under pytest (python/tests/test_kernels.py). The oracles are also
the ground truth the Rust native backend is cross-checked against (the same
formulas are implemented in rust/src/kernel/native.rs).

Conventions
-----------
- ``xq``: query block, float32 [nq, d]
- ``xd``: data block, float32 [nd, d]
- ``nq2``/``nd2``: precomputed squared norms ||x||^2, float32 [nq]/[nd].
  Passing norms in (rather than recomputing) makes zero-padding of the
  feature dimension exact and saves FLOPs on the hot path.
- scalars (gamma, eta) are runtime inputs so one AOT artifact serves the
  whole (C, gamma) grid of the paper's Tables 7-10.
"""

import jax.numpy as jnp


def rbf_block_ref(xq, xd, nq2, nd2, gamma):
    """RBF kernel block: K[i,j] = exp(-gamma * ||xq_i - xd_j||^2)."""
    d2 = nq2[:, None] + nd2[None, :] - 2.0 * jnp.dot(xq, xd.T)
    # Squared distances are mathematically >= 0; clamp the float error so
    # exp never sees a positive argument scaled by -gamma.
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-gamma * d2)


def poly_block_ref(xq, xd, gamma, eta, degree=3):
    """Polynomial kernel block: K[i,j] = (gamma * <xq_i, xd_j> + eta)^degree."""
    g = gamma * jnp.dot(xq, xd.T) + eta
    return g ** degree


def linear_block_ref(xq, xd):
    """Linear kernel block: K[i,j] = <xq_i, xd_j>."""
    return jnp.dot(xq, xd.T)


def rbf_decision_ref(xq, xd, nq2, nd2, coef, gamma):
    """Fused decision values: rbf_block(...) @ coef  -> [nq].

    ``coef`` holds alpha_i * y_i for the support vectors in ``xd``;
    zero-padded entries contribute nothing, making tile padding exact.
    """
    return rbf_block_ref(xq, xd, nq2, nd2, gamma) @ coef


def poly_decision_ref(xq, xd, coef, gamma, eta, degree=3):
    """Fused decision values for the polynomial kernel -> [nq]."""
    return poly_block_ref(xq, xd, gamma, eta, degree) @ coef
