"""L1 Pallas kernels: tiled polynomial and linear kernel blocks.

Polynomial: K[i, j] = (gamma * <xq_i, xd_j> + eta)^degree (paper uses
degree 3, eta = 0 — the LIBSVM default — with gamma tuned; both eta and
gamma are runtime inputs so one artifact covers the grid sweeps).

Linear: K[i, j] = <xq_i, xd_j> (substrate for LLSVM / FastFood / LTPU whose
second stage is a linear SVM over explicit features).

Same tiling story as rbf.py: the cross term is one MXU matmul per
(QT, DT) = (64, 512) output tile, the integer power is a VPU elementwise
chain (g*g*g — no transcendental pow on the hot path).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rbf import QT, DT

DEGREE = 3  # paper's polynomial experiments use degree 3


def _poly_block_kernel(xq_ref, xd_ref, gamma_ref, eta_ref, out_ref):
    cross = jax.lax.dot_general(
        xq_ref[...], xd_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    g = gamma_ref[0] * cross + eta_ref[0]
    # Integer power by explicit multiply chain (VPU-friendly, exact).
    out_ref[...] = g * g * g


def poly_block(xq, xd, gamma, eta, *, interpret=True):
    """Tiled degree-3 polynomial kernel block -> f32[nq, nd]."""
    nq, d = xq.shape
    nd, _ = xd.shape
    grid = (nq // QT, nd // DT)
    return pl.pallas_call(
        _poly_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QT, d), lambda i, j: (i, 0)),
            pl.BlockSpec((DT, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((QT, DT), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nd), jnp.float32),
        interpret=interpret,
    )(xq, xd, gamma, eta)


def _lin_block_kernel(xq_ref, xd_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        xq_ref[...], xd_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def lin_block(xq, xd, *, interpret=True):
    """Tiled linear kernel block -> f32[nq, nd]."""
    nq, d = xq.shape
    nd, _ = xd.shape
    grid = (nq // QT, nd // DT)
    return pl.pallas_call(
        _lin_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QT, d), lambda i, j: (i, 0)),
            pl.BlockSpec((DT, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((QT, DT), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nd), jnp.float32),
        interpret=interpret,
    )(xq, xd)
