"""L1 Pallas kernels: fused kernel-block x coefficient decision values.

Prediction (and the warm-start gradient reconstruction in the Rust solver)
needs decision values

    dv_i = sum_j coef_j * K(xq_i, xd_j),        coef_j = alpha_j * y_j

The naive path materializes the [nq, nd] kernel block in HBM and then does a
GEMV. The fused kernel below keeps each (QT, DT) kernel tile in VMEM and
accumulates the partial GEMV across the data-tile grid dimension, so the
kernel block never leaves VMEM — the TPU analogue of the paper's "only touch
the kernel entries you need". Zero-padded coef entries contribute nothing,
which makes the Rust runtime's tile padding exact.

Accumulation pattern: the output block index_map ignores the data-grid index
j, so Pallas revisits the same output tile for j = 0..grid_j-1 (the grid is
iterated sequentially, last dim fastest); we initialize at j == 0 and
accumulate afterwards.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rbf import QT, DT


def _rbf_decision_kernel(xq_ref, xd_ref, nq2_ref, nd2_ref, coef_ref,
                         gamma_ref, out_ref):
    j = pl.program_id(1)
    cross = jax.lax.dot_general(
        xq_ref[...], xd_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = nq2_ref[...][:, None] + nd2_ref[...][None, :] - 2.0 * cross
    ktile = jnp.exp(-gamma_ref[0] * jnp.maximum(d2, 0.0))
    part = ktile @ coef_ref[...]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + part


def rbf_decision(xq, xd, nq2, nd2, coef, gamma, *, interpret=True):
    """Fused RBF decision values -> f32[nq]."""
    nq, d = xq.shape
    nd, _ = xd.shape
    grid = (nq // QT, nd // DT)
    return pl.pallas_call(
        _rbf_decision_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QT, d), lambda i, j: (i, 0)),
            pl.BlockSpec((DT, d), lambda i, j: (j, 0)),
            pl.BlockSpec((QT,), lambda i, j: (i,)),
            pl.BlockSpec((DT,), lambda i, j: (j,)),
            pl.BlockSpec((DT,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((QT,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.float32),
        interpret=interpret,
    )(xq, xd, nq2, nd2, coef, gamma)


def _poly_decision_kernel(xq_ref, xd_ref, coef_ref, gamma_ref, eta_ref,
                          out_ref):
    j = pl.program_id(1)
    cross = jax.lax.dot_general(
        xq_ref[...], xd_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    g = gamma_ref[0] * cross + eta_ref[0]
    part = (g * g * g) @ coef_ref[...]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + part


def poly_decision(xq, xd, coef, gamma, eta, *, interpret=True):
    """Fused degree-3 polynomial decision values -> f32[nq]."""
    nq, d = xq.shape
    nd, _ = xd.shape
    grid = (nq // QT, nd // DT)
    return pl.pallas_call(
        _poly_decision_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QT, d), lambda i, j: (i, 0)),
            pl.BlockSpec((DT, d), lambda i, j: (j, 0)),
            pl.BlockSpec((DT,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((QT,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.float32),
        interpret=interpret,
    )(xq, xd, coef, gamma, eta)
