"""L1 Pallas kernel: tiled RBF kernel block.

Computes K[i, j] = exp(-gamma * ||xq_i - xd_j||^2) for a query block against
a data block, using the norm decomposition

    ||xq_i - xd_j||^2 = ||xq_i||^2 + ||xd_j||^2 - 2 <xq_i, xd_j>

so the O(nq * nd * d) work is a single MXU matmul (the cross term); the VPU
handles the rank-1 norm broadcasts and the exp over the output tile.

TPU mapping (see DESIGN.md "Hardware adaptation"):
- tile (QT, D) x (DT, D) -> (QT, DT) = (64, 128) x (512, 128) -> (64, 512);
  VMEM footprint ~= 64*128 + 512*128 + 64*512 floats ~= 424 KiB << 16 MiB,
  leaving room for double buffering of the HBM->VMEM streams;
- both output dims are (8, 128)-lane aligned;
- gamma is a runtime input (shape (1,)), so a single compiled artifact
  serves every point of the paper's (C, gamma) grid.

Must be lowered with interpret=True: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO ops instead.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes for the BlockSpec grid (not the artifact shape; aot.py picks
# artifact shapes that are multiples of these).
QT = 64    # query-rows per tile (8 sublanes * 8)
DT = 512   # data-rows per tile (4 * 128 lanes)


def _rbf_block_kernel(xq_ref, xd_ref, nq2_ref, nd2_ref, gamma_ref, out_ref):
    xq = xq_ref[...]
    xd = xd_ref[...]
    # Cross term on the MXU; contract the feature dim of both operands so no
    # transpose of xd ever materializes.
    cross = jax.lax.dot_general(
        xq, xd, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = nq2_ref[...][:, None] + nd2_ref[...][None, :] - 2.0 * cross
    # Clamp float error: d2 is mathematically >= 0.
    d2 = jnp.maximum(d2, 0.0)
    out_ref[...] = jnp.exp(-gamma_ref[0] * d2)


def rbf_block(xq, xd, nq2, nd2, gamma, *, interpret=True):
    """Tiled RBF kernel block.

    Args:
      xq:   f32[nq, d]  query rows (nq % QT == 0)
      xd:   f32[nd, d]  data rows  (nd % DT == 0)
      nq2:  f32[nq]     precomputed ||xq_i||^2
      nd2:  f32[nd]     precomputed ||xd_j||^2
      gamma: f32[1]     RBF width (runtime input, not baked)

    Returns:
      f32[nq, nd] kernel block.
    """
    nq, d = xq.shape
    nd, _ = xd.shape
    grid = (nq // QT, nd // DT)
    return pl.pallas_call(
        _rbf_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QT, d), lambda i, j: (i, 0)),
            pl.BlockSpec((DT, d), lambda i, j: (j, 0)),
            pl.BlockSpec((QT,), lambda i, j: (i,)),
            pl.BlockSpec((DT,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((QT, DT), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nd), jnp.float32),
        interpret=interpret,
    )(xq, xd, nq2, nd2, gamma)
