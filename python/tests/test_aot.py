"""AOT path tests: lowering determinism, manifest consistency, HLO sanity.

These protect the Rust runtime ABI: if an artifact's input order, shape, or
entry signature drifts, these fail before `cargo test` ever sees a bad
artifact.
"""

import json
import os
import re
import tempfile

import jax
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def built():
    with tempfile.TemporaryDirectory() as td:
        manifest = aot.build(td)
        texts = {}
        for name, meta in manifest["artifacts"].items():
            with open(os.path.join(td, meta["file"])) as f:
                texts[name] = f.read()
        yield manifest, texts


def test_catalog_complete(built):
    manifest, _ = built
    expected = {
        "rbf_block_slim", "rbf_block_wide", "poly_block_slim",
        "poly_block_wide", "lin_block_wide", "rbf_decision_wide",
        "poly_decision_wide",
    }
    assert set(manifest["artifacts"]) == expected


def test_manifest_tile_constants(built):
    manifest, _ = built
    assert manifest["d_pad"] == M.D_PAD
    assert manifest["nq_slim"] == M.NQ_SLIM
    assert manifest["nq_wide"] == M.NQ_WIDE
    assert manifest["nd_blk"] == M.ND_BLK


def test_hlo_is_text_with_entry(built):
    _, texts = built
    for name, text in texts.items():
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # No Mosaic custom-calls: interpret=True must lower to plain HLO.
        assert "tpu_custom_call" not in text, name
        assert "mosaic" not in text.lower(), name


def test_parameter_counts_match_manifest(built):
    manifest, texts = built
    for name, meta in manifest["artifacts"].items():
        # Count parameter(i) instructions inside the ENTRY computation body.
        lines = texts[name].splitlines()
        start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        body = []
        for l in lines[start + 1:]:
            if l.startswith("}"):
                break
            body.append(l)
        nparams = sum(1 for l in body if re.search(r"parameter\(\d+\)", l))
        assert nparams == len(meta["inputs"]), name


def test_lowering_deterministic():
    """Two lowers of the same graph produce identical HLO text."""
    spec = [jax.ShapeDtypeStruct(tuple(s), jax.numpy.float32)
            for s in [(64, 128), (1024, 128), (64,), (1024,), (1,)]]
    t1 = aot.to_hlo_text(jax.jit(M.rbf_block_graph).lower(*spec))
    t2 = aot.to_hlo_text(jax.jit(M.rbf_block_graph).lower(*spec))
    assert t1 == t2


def test_repo_artifacts_in_sync_if_present():
    """If artifacts/ is already built, it must match the current catalog."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts/ not built")
    with open(mpath) as f:
        manifest = json.load(f)
    assert set(manifest["artifacts"]) == set(aot.catalog().keys())
    for name, meta in manifest["artifacts"].items():
        assert os.path.exists(os.path.join(root, meta["file"])), name
