"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the core correctness signal for the compile path: if these pass, the
HLO artifacts the Rust runtime executes compute exactly the oracle formulas.
Hypothesis sweeps shapes and kernel parameters; fixed tests pin the exact
tile shapes the AOT catalog uses.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref
from compile.kernels.rbf import rbf_block, QT, DT
from compile.kernels.poly import poly_block, lin_block
from compile.kernels.decision import rbf_decision, poly_decision

RTOL, ATOL = 1e-5, 1e-5


def _data(nq, nd, d, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    xq = (rng.normal(size=(nq, d)) * scale).astype(np.float32)
    xd = (rng.normal(size=(nd, d)) * scale).astype(np.float32)
    return jnp.asarray(xq), jnp.asarray(xd)


def _norms(x):
    return (x * x).sum(axis=1)


# ---------------------------------------------------------------------------
# Exact-tile tests (the shapes the AOT artifacts are compiled at)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq", [M.NQ_SLIM, M.NQ_WIDE])
@pytest.mark.parametrize("gamma", [0.01, 0.5, 32.0])
def test_rbf_block_tile_shapes(nq, gamma):
    xq, xd = _data(nq, M.ND_BLK, M.D_PAD, seed=nq)
    got = rbf_block(xq, xd, _norms(xq), _norms(xd),
                    jnp.array([gamma], jnp.float32))
    want = ref.rbf_block_ref(xq, xd, _norms(xq), _norms(xd), gamma)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("nq", [M.NQ_SLIM, M.NQ_WIDE])
@pytest.mark.parametrize("gamma,eta", [(1.0, 0.0), (0.25, 1.0)])
def test_poly_block_tile_shapes(nq, gamma, eta):
    xq, xd = _data(nq, M.ND_BLK, M.D_PAD, seed=nq, scale=0.3)
    got = poly_block(xq, xd, jnp.array([gamma], jnp.float32),
                     jnp.array([eta], jnp.float32))
    want = ref.poly_block_ref(xq, xd, gamma, eta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lin_block_tile_shape():
    xq, xd = _data(M.NQ_WIDE, M.ND_BLK, M.D_PAD)
    got = lin_block(xq, xd)
    np.testing.assert_allclose(got, ref.linear_block_ref(xq, xd),
                               rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("gamma", [0.1, 2.0])
def test_rbf_decision_tile_shape(gamma):
    xq, xd = _data(M.NQ_WIDE, M.ND_BLK, M.D_PAD)
    rng = np.random.default_rng(7)
    coef = jnp.asarray(rng.normal(size=(M.ND_BLK,)).astype(np.float32))
    got = rbf_decision(xq, xd, _norms(xq), _norms(xd), coef,
                       jnp.array([gamma], jnp.float32))
    want = ref.rbf_decision_ref(xq, xd, _norms(xq), _norms(xd), coef, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_poly_decision_tile_shape():
    xq, xd = _data(M.NQ_WIDE, M.ND_BLK, M.D_PAD, scale=0.3)
    rng = np.random.default_rng(8)
    coef = jnp.asarray(rng.normal(size=(M.ND_BLK,)).astype(np.float32))
    got = poly_decision(xq, xd, coef, jnp.array([0.5], jnp.float32),
                        jnp.array([0.0], jnp.float32))
    want = ref.poly_decision_ref(xq, xd, coef, 0.5, 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Multi-tile grid accumulation: the decision kernel must revisit its output
# block across the data-grid dimension (j) and accumulate exactly.
# ---------------------------------------------------------------------------

def test_rbf_decision_multitile_accumulation():
    nq, nd = 2 * QT, 2 * DT   # grid (2, 2): j-accumulation exercised
    xq, xd = _data(nq, nd, 32, seed=3)
    rng = np.random.default_rng(3)
    coef = jnp.asarray(rng.normal(size=(nd,)).astype(np.float32))
    got = rbf_decision(xq, xd, _norms(xq), _norms(xd), coef,
                       jnp.array([1.0], jnp.float32))
    want = ref.rbf_decision_ref(xq, xd, _norms(xq), _norms(xd), coef, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Padding exactness: the padded wrappers reproduce exactly how the Rust
# runtime embeds arbitrary shapes into the fixed artifact tiles.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    nq=st.integers(1, 140),
    nd=st.integers(1, 600),
    d=st.integers(1, 128),
    gamma=st.floats(1e-3, 64.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_padding_exact(nq, nd, d, gamma, seed):
    xq, xd = _data(nq, nd, d, seed=seed)
    got = M.rbf_block_padded(xq, xd, gamma)
    want = ref.rbf_block_ref(xq, xd, _norms(xq), _norms(xd), gamma)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    nq=st.integers(1, 100),
    nd=st.integers(1, 520),
    d=st.integers(1, 64),
    gamma=st.floats(1e-2, 4.0),
    eta=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_poly_padding_exact(nq, nd, d, gamma, eta, seed):
    rng = np.random.default_rng(seed)
    xq = jnp.asarray((rng.normal(size=(nq, d)) * 0.3).astype(np.float32))
    xd = jnp.asarray((rng.normal(size=(nd, d)) * 0.3).astype(np.float32))
    got = M.poly_block_padded(xq, xd, gamma, eta)
    want = ref.poly_block_ref(xq, xd, gamma, eta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    nq=st.integers(1, 100),
    nd=st.integers(1, 520),
    d=st.integers(1, 64),
    gamma=st.floats(1e-2, 8.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_decision_padding_exact(nq, nd, d, gamma, seed):
    xq, xd = _data(nq, nd, d, seed=seed)
    rng = np.random.default_rng(seed + 1)
    coef = jnp.asarray(rng.normal(size=(nd,)).astype(np.float32))
    got = M.rbf_decision_padded(xq, xd, coef, gamma)
    want = ref.rbf_decision_ref(xq, xd, _norms(xq), _norms(xd), coef, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Mathematical invariants of the kernels themselves
# ---------------------------------------------------------------------------

def test_rbf_range_and_diagonal():
    x, _ = _data(96, 1, 16, seed=11)
    k = M.rbf_block_padded(x, x, 0.7)
    assert float(k.min()) >= 0.0 and float(k.max()) <= 1.0 + 1e-6
    np.testing.assert_allclose(np.diag(np.asarray(k)), 1.0, atol=1e-5)


def test_rbf_symmetry():
    x, _ = _data(80, 1, 24, seed=12)
    k = np.asarray(M.rbf_block_padded(x, x, 0.3))
    np.testing.assert_allclose(k, k.T, atol=1e-6)


def test_rbf_gamma_zero_is_all_ones():
    xq, xd = _data(10, 20, 8, seed=13)
    k = M.rbf_block_padded(xq, xd, 0.0)
    np.testing.assert_allclose(k, 1.0, atol=1e-6)
