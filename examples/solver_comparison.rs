//! All nine solvers on one dataset — a Table-3 row group in miniature.
//!
//! ```bash
//! cargo run --release --offline --example solver_comparison [-- dataset]
//! ```

use dcsvm::bench::{fmt_secs, Table};
use dcsvm::config::{Algo, RunConfig};
use dcsvm::harness;

fn main() -> anyhow::Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "covtype-like".into());
    let mut base = RunConfig::default();
    base.dataset = dataset.clone();
    base.n_train = Some(2000);
    base.n_test = Some(600);
    base.gamma = 16.0;
    base.c = 4.0;
    base.levels = 2;
    base.sample_m = 128;
    base.budget = 64;
    let (tr, te) = harness::load_dataset(&base)?;
    println!(
        "solver comparison on {dataset} (n={}, d={}, γ={}, C={})",
        tr.len(),
        tr.dim,
        base.gamma,
        base.c
    );

    let mut table = Table::new(&["solver", "time", "acc%", "SVs/size", "notes"]);
    for algo in Algo::all() {
        let mut cfg = base.clone();
        cfg.algo = algo;
        let out = harness::run(&cfg, &tr, &te)?;
        table.row(&[
            out.algo.to_string(),
            fmt_secs(out.train_s),
            format!("{:.2}", 100.0 * out.accuracy),
            out.svs.to_string(),
            out.note,
        ]);
    }
    table.print();
    println!(
        "\npaper Table 3 shape: DC-SVM(early) fastest at near-best accuracy; \
         DC-SVM = LIBSVM accuracy at a fraction of the time; approximate \
         solvers below exact accuracy."
    );
    Ok(())
}
