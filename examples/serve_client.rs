//! Multi-client serving demo: drive a running `dcsvm serve --listen`
//! server over the newline-delimited JSON protocol (PROTOCOL.md) and
//! watch the shared serving cache warm across requests.
//!
//! ```bash
//! # Terminal 1: train a covtype-like model and serve it over TCP.
//! cargo run --release -- train --algo dcsvm --dataset covtype-like \
//!     --n-train 2000 --n-test 500 --gamma 32 --backend native \
//!     --save-model model.json
//! cargo run --release -- serve --model model.json --listen 127.0.0.1:7878
//!
//! # Terminal 2: replay one query batch twice through a client connection.
//! cargo run --release --offline --example serve_client -- 127.0.0.1:7878 32
//! ```
//!
//! The second pass replays the same batch: `rows_computed` drops to 0 and
//! `hit_rate` rises to 1.0. Run the example again (a new connection, even
//! a new process): its "cold" pass is *already warm* — every connection
//! shares the server's one `ServingContext`, so kernel rows computed for
//! one client answer every other client's repeats.

use anyhow::{bail, Result};
use dcsvm::data::synthetic::{covtype_like, generate_split};
use dcsvm::serving::transport::ServeClient;

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let addr = args.next().unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(32);

    // The same synthetic batch every run: replays hit the server's cache
    // across example invocations, not just across passes.
    let (_, te) = generate_split(&covtype_like(), 50, n, 0);
    let rows: Vec<Vec<f32>> = te.x.chunks(te.dim).map(|r| r.to_vec()).collect();

    let mut client = ServeClient::connect(addr.as_str())?;
    println!("connected to {addr}; sending {n} covtype-like queries twice");
    for pass in ["first pass", "replay"] {
        let resp = client.decide(&rows)?;
        if resp.get("error").as_obj().is_some() {
            bail!(
                "server error: {} (is the served model covtype-like, dim {}?)",
                resp.get("error"),
                te.dim
            );
        }
        let stats = resp.get("stats");
        println!(
            "{pass}: rows={} rows_computed={} hit_rate={:.2} latency_ms={:.3}",
            stats.get("rows"),
            stats.get("rows_computed"),
            stats.get("hit_rate").as_f64().unwrap_or(0.0),
            stats.get("latency_ms").as_f64().unwrap_or(0.0),
        );
    }
    println!("(rerun this example: the new connection starts warm — the cache is shared)");
    Ok(())
}
