//! (C, γ) robustness sweep — Tables 7–10 / Figures 5–8 in miniature:
//! DC-SVM (early) / DC-SVM / LIBSVM across a parameter grid, with the
//! Table-5 accumulated-time footer.
//!
//! ```bash
//! cargo run --release --offline --example grid_sweep [-- dataset]
//! ```

use dcsvm::bench::{fmt_secs, Table};
use dcsvm::config::{Algo, RunConfig};
use dcsvm::harness;

fn main() -> anyhow::Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "ijcnn1-like".into());
    let mut base = RunConfig::default();
    base.dataset = dataset.clone();
    base.n_train = Some(1500);
    base.n_test = Some(500);
    base.levels = 2;
    base.sample_m = 96;
    let (tr, te) = harness::load_dataset(&base)?;
    println!("grid sweep on {dataset} (n={}, d={})", tr.len(), tr.dim);

    let cs = [-6i32, 1, 6];
    let gs = [-6i32, 1, 6];
    let mut table = Table::new(&["C", "γ", "early time", "early acc%", "dc time", "dc acc%", "libsvm time", "libsvm acc%"]);
    let mut totals = [0f64; 3];
    let mut faster = 0usize;
    let mut settings = 0usize;

    for &cexp in &cs {
        for &gexp in &gs {
            let mut row = vec![format!("2^{cexp}"), format!("2^{gexp}")];
            let mut times = [0f64; 3];
            for (ai, algo) in [Algo::DcSvmEarly, Algo::DcSvm, Algo::Libsvm].iter().enumerate() {
                let mut cfg = base.clone();
                cfg.algo = *algo;
                cfg.c = 2f64.powi(cexp);
                cfg.gamma = 2f64.powi(gexp);
                let out = harness::run(&cfg, &tr, &te)?;
                totals[ai] += out.train_s;
                times[ai] = out.train_s;
                row.push(fmt_secs(out.train_s));
                row.push(format!("{:.1}", 100.0 * out.accuracy));
            }
            settings += 1;
            if times[1] <= times[2] {
                faster += 1;
            }
            table.row(&row);
        }
    }
    table.print();
    println!("\naccumulated time (Table 5 shape):");
    for (name, total) in ["DC-SVM (early)", "DC-SVM", "LIBSVM"].iter().zip(totals) {
        println!("  {name}: {}", fmt_secs(total));
    }
    println!("DC-SVM faster than LIBSVM on {faster}/{settings} settings (paper: 96/100)");
    Ok(())
}
