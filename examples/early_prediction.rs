//! Early prediction (paper §4, Table 1): compare the three ways to predict
//! from a lower-level (k-cluster) model —
//!   (10) naive global aggregation of all local SVs,
//!   BCM  Bayesian Committee Machine combination,
//!   (11) the paper's early prediction: route to the nearest cluster, use
//!        only that cluster's local model.
//!
//! ```bash
//! cargo run --release --offline --example early_prediction
//! ```

use std::time::Instant;

use dcsvm::data::synthetic;
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::harness;
use dcsvm::kernel::KernelKind;
use dcsvm::predict::{BcmModel, SvmModel};
use dcsvm::bench::Table;

fn main() -> anyhow::Result<()> {
    let spec = synthetic::covtype_like();
    let (tr, te) = synthetic::generate_split(&spec, 4000, 1200, 3);
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kernel = harness::make_kernel(kind, "auto", tr.dim)?;

    let mut table = Table::new(&["k", "method", "acc%", "ms/sample"]);

    for &(levels, k) in &[(2usize, 16usize), (3, 64)] {
        // Single divide phase to level 1 => k_base^levels clusters... we use
        // `levels` with k_base 4 then stop at level `levels` itself, i.e. a
        // single-level DC-SVM with k = 4^levels clusters (Table 1 uses
        // single-level k = 50, 100).
        let cfg = DcSvmConfig {
            kind,
            c: 4.0,
            levels,
            k_base: 4,
            sample_m: 128,
            stop_after_level: Some(levels), // single-level: bottom only
            keep_level_alphas: true,
            ..Default::default()
        };
        let dc = train(&tr, kernel.as_ref(), &cfg);
        let em = dc.early_model.as_ref().expect("early model");
        let norms = te.sq_norms();

        // (10) naive: one global model from the concatenated ᾱ
        let naive = SvmModel::from_alpha(&tr, &dc.alpha, kind);
        let t0 = Instant::now();
        let acc10 = {
            let preds = naive.predict_batch(&te.x, &norms, kernel.as_ref());
            dcsvm::metrics::accuracy(&preds, &te.y)
        };
        let ms10 = 1e3 * t0.elapsed().as_secs_f64() / te.len() as f64;

        // BCM: committee of the k local models
        let bcm = BcmModel::new(em.locals.clone());
        let t0 = Instant::now();
        let acc_bcm = bcm.accuracy(&te, kernel.as_ref());
        let ms_bcm = 1e3 * t0.elapsed().as_secs_f64() / te.len() as f64;

        // (11) early prediction: routed local model
        let t0 = Instant::now();
        let acc11 = em.accuracy(&te, kernel.as_ref());
        let ms11 = 1e3 * t0.elapsed().as_secs_f64() / te.len() as f64;

        for (m, acc, ms) in [
            ("naive (10)", acc10, ms10),
            ("BCM", acc_bcm, ms_bcm),
            ("early (11)", acc11, ms11),
        ] {
            table.row(&[
                k.to_string(),
                m.to_string(),
                format!("{:.1}", 100.0 * acc),
                format!("{ms:.3}"),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper Table 1 shape: early (11) best accuracy at lowest per-sample \
         cost; BCM and naive degrade as k grows."
    );
    Ok(())
}
