//! Quickstart: train DC-SVM on a synthetic workload, verify it reaches the
//! same optimum as the direct exact solver, and predict.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use dcsvm::data::synthetic;
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::harness;
use dcsvm::kernel::KernelKind;
use dcsvm::predict::SvmModel;
use dcsvm::solver::{solve_svm, SmoConfig};

fn main() -> anyhow::Result<()> {
    // 1. Data: a covtype-like synthetic binary problem (see DESIGN.md §5).
    let spec = synthetic::covtype_like();
    let (train_set, test_set) = synthetic::generate_split(&spec, 3000, 800, 7);
    println!(
        "dataset: {} — {} train / {} test, dim {}",
        spec.name,
        train_set.len(),
        test_set.len(),
        train_set.dim
    );

    // 2. Kernel backend: PJRT (AOT Pallas artifacts) when built, else native.
    let kind = KernelKind::Rbf { gamma: 16.0 };
    let kernel = harness::make_kernel(kind, "auto", train_set.dim)?;
    println!(
        "backend: {}",
        if harness::global_engine().is_some() { "pjrt" } else { "native" }
    );

    // 3. Train DC-SVM (multilevel divide-and-conquer, Algorithm 1).
    let cfg = DcSvmConfig {
        kind,
        c: 4.0,
        levels: 3,
        k_base: 4,
        sample_m: 128,
        eps_final: 1e-5,
        ..Default::default()
    };
    let dc = train(&train_set, kernel.as_ref(), &cfg);
    println!(
        "DC-SVM: {:.2}s total ({} levels), objective {:.4}, {} SVs",
        dc.total_s,
        dc.levels.len(),
        dc.objective.unwrap(),
        dc.sv_count()
    );

    // 4. Cross-check against the direct exact solver (our "LIBSVM").
    let direct = solve_svm(
        &train_set,
        kernel.as_ref(),
        SmoConfig { c: cfg.c, eps: 1e-5, ..Default::default() },
    );
    println!(
        "direct: {:.2}s, objective {:.4} — DC-SVM warm start cut final-stage \
         iterations to {} (direct: {})",
        direct.elapsed_s, direct.objective, dc.final_iterations, direct.iterations
    );

    // 5. Predict.
    let model = SvmModel::from_alpha(&train_set, &dc.alpha, kind);
    let acc = model.accuracy(&test_set, kernel.as_ref());
    println!("test accuracy: {:.2}%", 100.0 * acc);

    assert!((dc.objective.unwrap() - direct.objective).abs()
        < 1e-3 * (1.0 + direct.objective.abs()));
    Ok(())
}
