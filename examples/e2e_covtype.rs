//! END-TO-END DRIVER: the full three-layer system on a real (synthetic)
//! covtype-scale workload — the repo's integration proof.
//!
//! Exercises every layer in one run:
//!   L1/L2  AOT Pallas kernels executed via PJRT (backend = pjrt, hard
//!          requirement here — the run aborts rather than silently falling
//!          back to native),
//!   L3     two-step kernel kmeans, multilevel DC-SVM, warm-started exact
//!          conquer, early prediction, and the LIBSVM-mode comparator,
//! and logs the paper's headline quantities: time-to-ε for DC-SVM vs the
//! cold solver, the objective-vs-time trace, early-prediction accuracy, and
//! per-level cluster/train timing (Table 6).
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_covtype
//! ```

use dcsvm::bench::{fmt_secs, Table};
use dcsvm::cache::KernelContext;
use dcsvm::data::synthetic;
use dcsvm::dcsvm::{train, DcSvmConfig};
use dcsvm::harness;
use dcsvm::kernel::KernelKind;
use dcsvm::metrics::relative_error;
use dcsvm::predict::SvmModel;
use dcsvm::solver::{SmoConfig, SmoSolver};

fn main() -> anyhow::Result<()> {
    let n_train: usize = std::env::var("E2E_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8000);

    // ---- layer check: PJRT must be live --------------------------------
    let engine = harness::global_engine()
        .expect("artifacts/ missing — run `make artifacts` first (this example requires the PJRT path)");
    println!(
        "PJRT engine: d_pad={} tiles {}x{} / {}x{}",
        engine.abi().d_pad,
        engine.abi().nq_slim,
        engine.abi().nd_blk,
        engine.abi().nq_wide,
        engine.abi().nd_blk
    );

    let spec = synthetic::covtype_like();
    let (tr, te) = synthetic::generate_split(&spec, n_train, n_train / 4, 42);
    println!("workload: {} n={} d={} (+{} test)", spec.name, tr.len(), tr.dim, te.len());

    let kind = KernelKind::Rbf { gamma: 32.0 };
    let kernel = harness::make_kernel(kind, "pjrt", tr.dim)?;
    let c = 4.0;

    // ---- DC-SVM (exact, multilevel) -------------------------------------
    let cfg = DcSvmConfig {
        kind,
        c,
        levels: 2,
        k_base: 4,
        sample_m: 256,
        eps_sub: 1e-3,
        eps_final: 1e-5,
        // Constrained kernel cache — the paper's memory regime (LIBSVM with
        // 8 GB on half a million points caches ~1% of rows).
        cache_bytes: 32 << 20,
        ..Default::default()
    };
    let dc = train(&tr, kernel.as_ref(), &cfg);
    let f_dc = dc.objective.unwrap();

    // ---- cold exact solver (our LIBSVM) ----------------------------------
    // Constrained kernel cache — the paper's memory regime (LIBSVM with
    // 8 GB on half a million points caches ~1% of rows).
    let cold_ctx = KernelContext::new(&tr, kernel.as_ref(), 32 << 20);
    let mut trace_cold = Vec::new();
    let cold = SmoSolver::new(
        cold_ctx.view_full(),
        SmoConfig { c, eps: 1e-5, ..Default::default() },
    )
    .solve_warm(None, &mut |p| trace_cold.push((p.elapsed_s, p.objective)));
    let f_star = cold.objective.min(f_dc);

    // ---- DC-SVM (early) ---------------------------------------------------
    let ecfg = DcSvmConfig { stop_after_level: Some(1), ..cfg.clone() };
    let early = train(&tr, kernel.as_ref(), &ecfg);
    let em = early.early_model.as_ref().unwrap();
    let early_acc = em.accuracy(&te, kernel.as_ref());

    // ---- report -----------------------------------------------------------
    let model = SvmModel::from_alpha(&tr, &dc.alpha, kind);
    let exact_acc = model.accuracy(&te, kernel.as_ref());

    let mut t = Table::new(&["solver", "time", "objective", "rel-err", "acc%"]);
    t.row(&[
        "DC-SVM (early)".into(),
        fmt_secs(early.total_s),
        "—".into(),
        "—".into(),
        format!("{:.2}", 100.0 * early_acc),
    ]);
    t.row(&[
        "DC-SVM".into(),
        fmt_secs(dc.total_s),
        format!("{f_dc:.4}"),
        format!("{:.1e}", relative_error(f_dc, f_star)),
        format!("{:.2}", 100.0 * exact_acc),
    ]);
    t.row(&[
        "LIBSVM (cold)".into(),
        fmt_secs(cold.elapsed_s),
        format!("{:.4}", cold.objective),
        format!("{:.1e}", relative_error(cold.objective, f_star)),
        "—".into(),
    ]);
    t.print();

    println!("\nper-level breakdown (Table 6 shape):");
    let mut lt = Table::new(&["level", "k", "clustering", "training", "SVs"]);
    for ls in &dc.levels {
        lt.row(&[
            ls.level.to_string(),
            ls.k.to_string(),
            fmt_secs(ls.clustering_s),
            fmt_secs(ls.training_s),
            ls.sv_count.to_string(),
        ]);
    }
    lt.row(&[
        "0 (final)".into(),
        "1".into(),
        "—".into(),
        fmt_secs(dc.final_s),
        dc.sv_count().to_string(),
    ]);
    lt.print();

    println!("\nobjective-vs-time trace (DC-SVM final stage, Figure 3 shape):");
    for &(t, f) in dc.trace.points.iter().take(8) {
        println!("  t={:>8} f={f:.4} rel-err={:.2e}", fmt_secs(t), relative_error(f, f_star));
    }

    println!("\nPJRT artifact executions:");
    for (name, calls) in engine.call_counts() {
        println!("  {name}: {calls}");
    }

    println!(
        "\nheadline: DC-SVM exact {} vs cold {} ({:.1}x); early {} at {:.2}% acc \
         ({:.1}x vs cold)",
        fmt_secs(dc.total_s),
        fmt_secs(cold.elapsed_s),
        cold.elapsed_s / dc.total_s.max(1e-9),
        fmt_secs(early.total_s),
        100.0 * early_acc,
        cold.elapsed_s / early.total_s.max(1e-9),
    );
    assert!(relative_error(f_dc, f_star) < 1e-3);
    Ok(())
}
